"""System tests for OAVI: the paper's claims as executable assertions."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ihb, oavi, terms
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig


def _cfg(engine="fast", solver="bpcg", psi=0.005, **kw):
    return OAVIConfig(
        psi=psi, engine=engine, cap_terms=64,
        solver=OracleConfig(name=solver), **kw,
    )


def test_generators_vanish_on_train(planted_cube):
    model = oavi.fit(planted_cube, _cfg())
    assert model.num_G > 0
    mses = np.asarray(model.mse(planted_cube))
    assert mses.max() <= model.psi * (1 + 1e-3)


def test_thm_4_3_bound_holds(planted_cube):
    model = oavi.fit(planted_cube, _cfg())
    assert model.num_G + model.num_O <= model.stats["thm43_bound"]


def test_O_is_order_ideal(planted_cube):
    """Every divisor of a term in O is in O (OAVI invariant)."""
    model = oavi.fit(planted_cube, _cfg())
    idx = model.book.index
    for term in model.book.terms:
        for div in terms.immediate_divisors(term):
            assert div in idx


def test_engines_agree(planted_cube):
    """fast (closed-form IHB) == oracle engines on the same data."""
    ref = oavi.fit(planted_cube, _cfg(engine="fast"))
    for solver in ["agd", "cg", "bpcg"]:
        m = oavi.fit(planted_cube, _cfg(engine="oracle", solver=solver))
        assert [g.term for g in m.generators] == [g.term for g in ref.generators]
        assert m.book.terms == ref.book.terms


def test_wihb_produces_sparser_generators(planted_cube):
    dense = oavi.fit(planted_cube, _cfg(engine="oracle", solver="cg", ihb=True))
    sparse = oavi.fit(planted_cube, _cfg(engine="oracle", solver="bpcg",
                                         ihb=True, wihb=True))

    def spar(model):
        z = e = 0
        for g in model.generators:
            e += len(g.coeffs)
            z += int(np.sum(g.coeffs == 0.0))
        return z / max(e, 1)

    # WIHB re-solves accepted generators with BPCG from a cold start -> its
    # coefficient vectors can only be sparser or equal
    assert spar(sparse) >= spar(dense)
    # and the generators still vanish
    assert np.asarray(sparse.mse(planted_cube)).max() <= 0.005 * (1 + 1e-3)


def test_evaluation_on_unseen_data(planted_cube):
    """Theorem 4.2 machinery: G evaluates on new points of the same variety."""
    model = oavi.fit(planted_cube, _cfg())
    rng = np.random.default_rng(7)
    Z = rng.uniform(0, 1, (300, 4))
    Z[:, 3] = np.clip(Z[:, 0] * Z[:, 1], 0, 1)  # noiseless variety points
    mses = np.asarray(model.mse(Z))
    assert mses.max() < 0.05  # out-sample vanishing (paper's Theorem 6)


def test_pearson_ordering_makes_output_permutation_invariant(planted_cube):
    """Section 5: with Pearson ordering the output is independent of the
    initial feature permutation."""
    rng = np.random.default_rng(3)
    perm = rng.permutation(planted_cube.shape[1])
    a = oavi.fit(planted_cube, _cfg(ordering="pearson"))
    b = oavi.fit(planted_cube[:, perm], _cfg(ordering="pearson"))
    assert a.num_G == b.num_G and a.num_O == b.num_O
    # generator evaluation agrees on common points (up to fp noise)
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(a.evaluate_G(planted_cube)))),
        np.sort(np.abs(np.asarray(b.evaluate_G(planted_cube[:, perm])))),
        rtol=5e-2, atol=5e-3,
    )


def test_without_ordering_output_depends_on_permutation(planted_cube):
    """The problem Section 5 fixes: no ordering -> permutation-sensitive."""
    rng = np.random.default_rng(3)
    perm = np.array([3, 0, 1, 2])
    a = oavi.fit(planted_cube, _cfg(ordering="none"))
    b = oavi.fit(planted_cube[:, perm], _cfg(ordering="none"))
    lead_a = {g.term for g in a.generators}
    lead_b = {g.term for g in b.generators}
    assert lead_a != lead_b or a.book.terms != b.book.terms


def test_psi_zero_like_behaviour_small_psi(planted_cube):
    """Tiny psi -> more terms in O, deeper degrees (no early acceptance)."""
    loose = oavi.fit(planted_cube, _cfg(psi=0.05))
    tight = oavi.fit(planted_cube, dataclasses.replace(_cfg(psi=0.0005), max_degree=4))
    assert tight.num_O >= loose.num_O


def test_capacity_growth():
    """cap_terms smaller than |O| triggers regrowth, not failure."""
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (400, 5))
    cfg = dataclasses.replace(_cfg(psi=0.001), cap_terms=8, max_degree=3)
    model = oavi.fit(X, cfg)
    assert model.num_G + model.num_O > 8
    assert model.stats["regrowths"] > 0


# -- kernel-fused degree step, slimmed IHB state, wavefront evaluation ------


def test_degree_step_parity_pallas_interpret(planted_cube):
    """The Pallas gram kernel (interpret mode) and the jnp gather fallback
    produce the same model — structure exact, coefficients bit-exact (m fits
    one kernel block, so both paths run the identical fp32 matmul)."""
    X = planted_cube[:256]
    jnp_cfg = dataclasses.replace(_cfg(), kernel="jnp", ordering="none")
    int_cfg = dataclasses.replace(_cfg(), kernel="interpret", ordering="none")
    a = oavi.fit(X, jnp_cfg)
    b = oavi.fit(X, int_cfg)
    assert a.book.terms == b.book.terms
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs)
        assert ga.mse == gb.mse


def test_gram_fallback_bit_exact_vs_inline_matmul(planted_cube):
    """ops.gram_update's gather fallback == the pre-PR inline formulation
    ``(A^T B, B^T B)`` with gathered candidate columns, bit for bit."""
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    A = jnp.asarray(rng.uniform(0, 1, (300, 16)), jnp.float32)
    X = jnp.asarray(planted_cube[:300], jnp.float32)
    parents = jnp.asarray(rng.integers(0, 16, 8), jnp.int32)
    vars_ = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
    B = jnp.take(A, parents, axis=1) * jnp.take(X, vars_, axis=1)
    QL, C = ops.gram_update(A, X, parents, vars_, use_pallas=False)
    assert np.array_equal(np.asarray(QL), np.asarray(A.T @ B))
    assert np.array_equal(np.asarray(C), np.asarray(B.T @ B))


def test_slimmed_ihb_matches_full_state():
    """The slimmed state (only N maintained) appends bit-identically to the
    full 3-factor state's N — the pre-PR per-candidate work was 3x this."""
    rng = np.random.default_rng(0)
    Lcap, m = 16, 200
    cols = [np.ones(m)]
    full = ihb.init_state(Lcap, jnp.asarray(1.0, jnp.float32), jnp.float32)
    slim = ihb.init_state(
        Lcap, jnp.asarray(1.0, jnp.float32), jnp.float32, factors=("n",)
    )
    assert slim.AtA is None and slim.R is None
    assert full.AtA is not None and full.R is not None
    for j in range(1, 7):
        b = rng.uniform(0, 1, m)
        A = np.stack(cols, axis=1)
        q = np.zeros(Lcap, np.float32)
        q[:j] = A.T @ b / m
        btb = np.float32(b @ b / m)
        full = ihb.append_column(full, jnp.asarray(q), jnp.asarray(btb), jnp.asarray(j))
        slim = ihb.append_column(slim, jnp.asarray(q), jnp.asarray(btb), jnp.asarray(j))
        cols.append(b)
        assert np.array_equal(np.asarray(full.N), np.asarray(slim.N))
        assert slim.AtA is None and slim.R is None


def test_ihb_factors_for():
    assert ihb.factors_for("oracle", "inverse", True) == ("ata", "n")
    assert ihb.factors_for("oracle", "inverse", False) == ("ata",)
    assert ihb.factors_for("oracle", "chol", True) == ("ata", "r")
    assert ihb.factors_for("fast", "inverse", True) == ("n",)
    assert ihb.factors_for("fast", "chol", False) == ("r",)
    # the WIHB sparse re-solve runs BPCG regardless of engine -> needs AtA
    assert ihb.factors_for("fast", "inverse", True, wihb=True) == ("ata", "n")


def test_fast_engine_with_wihb_resolve(planted_cube):
    """engine='fast' + wihb: closed-form decisions, BPCG sparse re-solve of
    accepted generators — the slimmed state must still carry AtA for it."""
    model = oavi.fit(planted_cube, _cfg(wihb=True))
    ref = oavi.fit(planted_cube, _cfg())
    assert [g.term for g in model.generators] == [g.term for g in ref.generators]
    assert np.asarray(model.mse(planted_cube)).max() <= 0.005 * (1 + 1e-3)


def test_wavefront_evaluate_terms_bit_exact(planted_cube):
    """Degree-wavefront evaluation == the sequential fori_loop, bit for bit,
    on a fitted model's term book."""
    model = oavi.fit(planted_cube, _cfg(psi=0.0005))
    parents, vars_ = model.term_arrays()
    rng = np.random.default_rng(11)
    Z = jnp.asarray(rng.uniform(0, 1, (500, 4)), jnp.float32)
    wave = np.asarray(oavi.evaluate_terms(Z, parents, vars_))
    seq = np.asarray(
        oavi.evaluate_terms_sequential(Z, jnp.asarray(parents), jnp.asarray(vars_))
    )
    assert np.array_equal(wave, seq)


def test_evaluate_terms_traced_indices_fall_back(planted_cube):
    """evaluate_terms still works with traced index arrays (inside jit)."""
    model = oavi.fit(planted_cube, _cfg())
    parents, vars_ = model.term_arrays()
    Z = jnp.asarray(planted_cube[:100], jnp.float32)

    fn = jax.jit(lambda z, p, v: oavi.evaluate_terms(z, p, v))
    traced = np.asarray(fn(Z, jnp.asarray(parents), jnp.asarray(vars_)))
    direct = np.asarray(oavi.evaluate_terms(Z, parents, vars_))
    assert np.array_equal(traced, direct)


def test_recompile_regression():
    """Zero-recompile guarantee: a fit that forces two capacity regrowths
    compiles at most once per (Lcap, Kcap) bucket, and a warm refit with the
    same config and shapes compiles nothing."""
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (2000, 6)).astype(np.float32)
    cfg = OAVIConfig(
        psi=1e-6, engine="fast", cap_terms=32, max_degree=3, ordering="none"
    )
    model = oavi.fit(X, cfg)
    assert model.stats["regrowths"] >= 2
    # one compile per shape bucket, at most (buckets can be skipped when a
    # degree grows the capacity twice before its single step)
    assert model.stats["recompiles"] <= 3
    assert model.stats["recompiles"] >= 1
    warm = oavi.fit(X, cfg)
    assert warm.stats["recompiles"] == 0
    assert warm.book.terms == model.book.terms


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 4),
       st.sampled_from([0.05, 0.01, 0.005]))
def test_property_invariants_random_data(seed, n, psi):
    """Properties on random data: vanishing, bound, order-ideal, determinism."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (200, n))
    model = oavi.fit(X, _cfg(psi=psi, ordering="none"))
    assert model.num_G + model.num_O <= terms.theorem_4_3_size_bound(psi, n)
    if model.num_G:
        assert np.asarray(model.mse(X)).max() <= psi * (1 + 1e-2)
    # determinism
    again = oavi.fit(X, _cfg(psi=psi, ordering="none"))
    assert [g.term for g in again.generators] == [g.term for g in model.generators]
