"""System tests for OAVI: the paper's claims as executable assertions."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import oavi, terms
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig


def _cfg(engine="fast", solver="bpcg", psi=0.005, **kw):
    return OAVIConfig(
        psi=psi, engine=engine, cap_terms=64,
        solver=OracleConfig(name=solver), **kw,
    )


def test_generators_vanish_on_train(planted_cube):
    model = oavi.fit(planted_cube, _cfg())
    assert model.num_G > 0
    mses = np.asarray(model.mse(planted_cube))
    assert mses.max() <= model.psi * (1 + 1e-3)


def test_thm_4_3_bound_holds(planted_cube):
    model = oavi.fit(planted_cube, _cfg())
    assert model.num_G + model.num_O <= model.stats["thm43_bound"]


def test_O_is_order_ideal(planted_cube):
    """Every divisor of a term in O is in O (OAVI invariant)."""
    model = oavi.fit(planted_cube, _cfg())
    idx = model.book.index
    for term in model.book.terms:
        for div in terms.immediate_divisors(term):
            assert div in idx


def test_engines_agree(planted_cube):
    """fast (closed-form IHB) == oracle engines on the same data."""
    ref = oavi.fit(planted_cube, _cfg(engine="fast"))
    for solver in ["agd", "cg", "bpcg"]:
        m = oavi.fit(planted_cube, _cfg(engine="oracle", solver=solver))
        assert [g.term for g in m.generators] == [g.term for g in ref.generators]
        assert m.book.terms == ref.book.terms


def test_wihb_produces_sparser_generators(planted_cube):
    dense = oavi.fit(planted_cube, _cfg(engine="oracle", solver="cg", ihb=True))
    sparse = oavi.fit(planted_cube, _cfg(engine="oracle", solver="bpcg",
                                         ihb=True, wihb=True))

    def spar(model):
        z = e = 0
        for g in model.generators:
            e += len(g.coeffs)
            z += int(np.sum(g.coeffs == 0.0))
        return z / max(e, 1)

    # WIHB re-solves accepted generators with BPCG from a cold start -> its
    # coefficient vectors can only be sparser or equal
    assert spar(sparse) >= spar(dense)
    # and the generators still vanish
    assert np.asarray(sparse.mse(planted_cube)).max() <= 0.005 * (1 + 1e-3)


def test_evaluation_on_unseen_data(planted_cube):
    """Theorem 4.2 machinery: G evaluates on new points of the same variety."""
    model = oavi.fit(planted_cube, _cfg())
    rng = np.random.default_rng(7)
    Z = rng.uniform(0, 1, (300, 4))
    Z[:, 3] = np.clip(Z[:, 0] * Z[:, 1], 0, 1)  # noiseless variety points
    mses = np.asarray(model.mse(Z))
    assert mses.max() < 0.05  # out-sample vanishing (paper's Theorem 6)


def test_pearson_ordering_makes_output_permutation_invariant(planted_cube):
    """Section 5: with Pearson ordering the output is independent of the
    initial feature permutation."""
    rng = np.random.default_rng(3)
    perm = rng.permutation(planted_cube.shape[1])
    a = oavi.fit(planted_cube, _cfg(ordering="pearson"))
    b = oavi.fit(planted_cube[:, perm], _cfg(ordering="pearson"))
    assert a.num_G == b.num_G and a.num_O == b.num_O
    # generator evaluation agrees on common points (up to fp noise)
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(a.evaluate_G(planted_cube)))),
        np.sort(np.abs(np.asarray(b.evaluate_G(planted_cube[:, perm])))),
        rtol=5e-2, atol=5e-3,
    )


def test_without_ordering_output_depends_on_permutation(planted_cube):
    """The problem Section 5 fixes: no ordering -> permutation-sensitive."""
    rng = np.random.default_rng(3)
    perm = np.array([3, 0, 1, 2])
    a = oavi.fit(planted_cube, _cfg(ordering="none"))
    b = oavi.fit(planted_cube[:, perm], _cfg(ordering="none"))
    lead_a = {g.term for g in a.generators}
    lead_b = {g.term for g in b.generators}
    assert lead_a != lead_b or a.book.terms != b.book.terms


def test_psi_zero_like_behaviour_small_psi(planted_cube):
    """Tiny psi -> more terms in O, deeper degrees (no early acceptance)."""
    loose = oavi.fit(planted_cube, _cfg(psi=0.05))
    tight = oavi.fit(planted_cube, dataclasses.replace(_cfg(psi=0.0005), max_degree=4))
    assert tight.num_O >= loose.num_O


def test_capacity_growth():
    """cap_terms smaller than |O| triggers regrowth, not failure."""
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (400, 5))
    cfg = dataclasses.replace(_cfg(psi=0.001), cap_terms=8, max_degree=3)
    model = oavi.fit(X, cfg)
    assert model.num_G + model.num_O > 8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 4),
       st.sampled_from([0.05, 0.01, 0.005]))
def test_property_invariants_random_data(seed, n, psi):
    """Properties on random data: vanishing, bound, order-ideal, determinism."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (200, n))
    model = oavi.fit(X, _cfg(psi=psi, ordering="none"))
    assert model.num_G + model.num_O <= terms.theorem_4_3_size_bound(psi, n)
    if model.num_G:
        assert np.asarray(model.mse(X)).max() <= psi * (1 + 1e-2)
    # determinism
    again = oavi.fit(X, _cfg(psi=psi, ordering="none"))
    assert [g.term for g in again.generators] == [g.term for g in model.generators]
