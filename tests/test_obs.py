"""Observability subsystem tests: spans, sketch fidelity, trace schema,
no-op guarantees, the fit timing contract, and journal compaction.

These are the regression tests behind the obs contracts stated in
``src/repro/obs`` and ``benchmarks/bench_obs.py``:

* spans nest per-thread and never leak across threads;
* the log-bucket histogram recovers quantiles to within one bucket and
  merges associatively;
* exported traces validate against the Chrome trace-event schema;
* ``obs.disabled()`` makes spans/events true no-ops;
* enabling obs never changes what a fit computes (bit-identity);
* every fit loop reports the same timing contract
  (``time_total == time_setup + time_degrees + time_finalize +
  time_unattributed``);
* ``Journal.compact`` preserves exactly the records the continuous loop's
  resume path needs.
"""

import json
import math
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import oavi
from repro.core.oavi import OAVIConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c
from repro.resilience import Journal, JournalError


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test starts from enabled, unsampled, empty recorder state."""
    obs.configure(enabled=True, sample_every=1, jax_trace=False)
    obs.reset()
    yield
    obs.configure(enabled=True, sample_every=1)
    obs.reset()


def _span_events():
    return [e for e in obs.trace_events() if e["ph"] == "X"]


# ---------------------------------------------------------------------------
# spans: nesting, thread-safety, sampling


def test_span_nesting_stack():
    assert obs.current_stack() == []
    with obs.span("outer"):
        assert obs.current_stack() == ["outer"]
        with obs.span("inner", d=2):
            assert obs.current_stack() == ["outer", "inner"]
        assert obs.current_stack() == ["outer"]
    assert obs.current_stack() == []
    names = [e["name"] for e in _span_events()]
    # inner exits (and records) before outer
    assert names == ["inner", "outer"]


def test_span_records_duration_and_args():
    with obs.span("work", rows=7) as sp:
        pass
    assert sp.duration_s >= 0.0
    (ev,) = _span_events()
    assert ev["name"] == "work"
    assert ev["args"] == {"rows": 7}
    assert ev["dur"] >= 0.0


def test_spans_are_thread_isolated():
    errors = []
    barrier = threading.Barrier(4)

    def worker(tag):
        try:
            barrier.wait(timeout=10)
            for i in range(50):
                with obs.span(f"{tag}/outer", i=i):
                    with obs.span(f"{tag}/inner"):
                        stack = obs.current_stack()
                        if stack != [f"{tag}/outer", f"{tag}/inner"]:
                            errors.append((tag, stack))
                if obs.current_stack():
                    errors.append((tag, "leak"))
        except Exception as exc:  # pragma: no cover - surfaced via errors
            errors.append((tag, repr(exc)))

    threads = [threading.Thread(target=worker, args=(f"t{k}",)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # every span from every thread was recorded, each on its own tid
    events = _span_events()
    assert len(events) == 4 * 50 * 2
    by_tag = {}
    for e in events:
        by_tag.setdefault(e["name"].split("/")[0], set()).add(e["tid"])
    assert all(len(tids) == 1 for tids in by_tag.values())
    assert len(set().union(*by_tag.values())) == 4


def test_sampling_keeps_every_nth():
    obs.configure(sample_every=5)
    for _ in range(20):
        with obs.span("sampled"):
            pass
    assert len(_span_events()) == 4


def test_events_are_instant_records():
    obs.event("fit/recompile", signature="(8, 3)")
    (ev,) = obs.trace_events()
    assert ev["ph"] == "i"
    assert ev["args"] == {"signature": "(8, 3)"}


# ---------------------------------------------------------------------------
# disabled: true no-ops, numerics unchanged


def test_disabled_span_is_noop_singleton():
    obs.disable()
    try:
        a = obs.span("x")
        b = obs.span("y", rows=3)
        assert a is b  # shared singleton: zero per-span allocation
        with a:
            assert obs.current_stack() == []
        obs.event("ignored")
        assert obs.trace_events() == []
    finally:
        obs.enable()


def test_disabled_context_restores_state():
    assert obs.enabled()
    with obs.disabled():
        assert not obs.enabled()
        with obs.disabled():
            assert not obs.enabled()
        assert not obs.enabled()
    assert obs.enabled()


def test_metrics_stay_live_when_disabled():
    c = obs.Counter()
    h = obs.Histogram()
    with obs.disabled():
        c.inc(3)
        h.observe(2.0)
    assert c.value == 3
    assert h.count == 1


def test_fit_bit_identical_with_obs_on_and_off():
    X, _ = appendix_c(m=400, seed=0)
    X = MinMaxScaler(dtype="float32").fit_transform(X)
    cfg = OAVIConfig(psi=0.01, engine="fast")
    model_on = oavi.fit(X, cfg)
    with obs.disabled():
        model_off = oavi.fit(X, cfg)
    assert model_on.book.terms == model_off.book.terms
    assert [g.term for g in model_on.generators] == [
        g.term for g in model_off.generators
    ]
    for ga, gb in zip(model_on.generators, model_off.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs)
        assert ga.mse == gb.mse


# ---------------------------------------------------------------------------
# histogram sketch: fidelity, merge algebra, summaries


def _rel_err(approx, exact):
    return abs(approx - exact) / exact


@pytest.mark.parametrize(
    "sampler",
    [
        lambda rng: rng.lognormal(mean=0.0, sigma=1.5, size=50_000),
        lambda rng: rng.pareto(a=1.5, size=50_000) + 1.0,
    ],
    ids=["lognormal", "pareto"],
)
def test_sketch_quantiles_within_one_bucket(sampler):
    vals = sampler(np.random.default_rng(0))
    h = obs.Histogram()
    h.observe_many(vals)
    budget = obs.bucket_relative_error()
    for q in (50.0, 90.0, 99.0, 99.9):
        exact = float(np.percentile(vals, q))
        assert _rel_err(h.quantile(q / 100.0), exact) <= budget


def test_histogram_exact_moments():
    vals = [0.5, 1.0, 2.0, 4.0, 8.0]
    h = obs.Histogram()
    h.observe_many(vals)
    assert h.count == 5
    assert h.sum == pytest.approx(sum(vals))
    assert h.min == 0.5
    assert h.max == 8.0
    assert h.mean == pytest.approx(np.mean(vals))


def test_histogram_underflow_bucket():
    h = obs.Histogram()
    h.observe_many([-1.0, 0.0, 1.0])
    assert h.count == 3
    assert h.quantile(0.0) == 0.0  # non-positive values report as 0.0
    assert h.quantile(1.0) >= 1.0


def test_histogram_merge_is_associative_and_exact():
    rng = np.random.default_rng(7)
    parts = [rng.lognormal(0.0, 1.0, 5000) for _ in range(3)]

    def sketch(chunks):
        h = obs.Histogram()
        for c in chunks:
            h.observe_many(c)
        return h

    a, b, c = (sketch([p]) for p in parts)
    left = sketch([parts[0]]).merge(sketch([parts[1]])).merge(sketch([parts[2]]))
    right = sketch([parts[0]]).merge(sketch([parts[1]]).merge(sketch([parts[2]])))
    whole = sketch(parts)
    for q in (0.5, 0.9, 0.99):
        assert left.quantile(q) == right.quantile(q) == whole.quantile(q)
    assert left.count == right.count == whole.count == 15000
    assert left.sum == pytest.approx(whole.sum)
    assert left.min == whole.min and left.max == whole.max
    # merge() did not mutate its argument's identity semantics
    assert a.count == b.count == c.count == 5000


def test_histogram_summary_keys():
    h = obs.Histogram()
    h.observe_many([1.0, 2.0, 3.0])
    s = h.summary()
    assert set(s) == {"count", "sum", "mean", "min", "max", "p50", "p90", "p99", "p999"}
    empty = obs.Histogram().summary()
    assert empty["count"] == 0


def test_percentile_summary_helper():
    s = obs.percentile_summary([1.0, 2.0, 4.0], unit_scale=1e3)
    assert s["count"] == 3
    assert s["max"] == pytest.approx(4000.0, rel=obs.bucket_relative_error())
    assert obs.percentile_summary([]) is None


def test_registry_labels_and_snapshot():
    reg = obs.Registry()
    reg.counter("fit.recompiles", backend="local").inc()
    reg.counter("fit.recompiles", backend="shard").inc(2)
    reg.histogram("fit.seconds", backend="local").observe(0.5)
    snap = reg.snapshot()
    by_key = {(r["name"], tuple(sorted(r.get("labels", {}).items()))): r for r in snap}
    assert by_key[("fit.recompiles", (("backend", "local"),))]["value"] == 1
    assert by_key[("fit.recompiles", (("backend", "shard"),))]["value"] == 2
    assert by_key[("fit.seconds", (("backend", "local"),))]["count"] == 1


# ---------------------------------------------------------------------------
# trace export: Chrome-trace schema


def test_export_trace_validates_against_schema(tmp_path):
    with obs.span("fit", m=100):
        with obs.span("fit/degree", d=2):
            pass
    obs.event("fit/compile", signature="sig")
    path = obs.export_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    payload = obs.validate_chrome_trace(doc)
    assert doc["displayTimeUnit"] == "ms"
    names = {e["name"] for e in payload}
    assert names == {"fit", "fit/degree", "fit/compile"}
    # metadata rows announce the process and each thread
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)
    assert any(e["name"] == "thread_name" for e in meta)
    pid = os.getpid()
    assert all(e["pid"] == pid for e in payload)


@pytest.mark.parametrize(
    "doc",
    [
        [],  # not a dict
        {"events": []},  # wrong container key
        {"traceEvents": [{"ph": "X"}]},  # missing required keys
        {"traceEvents": [{"name": "x", "ph": "Z", "ts": 0, "pid": 1, "tid": 1}]},
        {"traceEvents": [{"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 1}]},
    ],
    ids=["not-dict", "wrong-key", "missing-keys", "bad-phase", "x-without-dur"],
)
def test_validate_chrome_trace_rejects_malformed(doc):
    with pytest.raises(ValueError):
        obs.validate_chrome_trace(doc)


def test_trace_buffer_bounded_with_drop_count():
    obs.configure(trace_capacity=16)
    try:
        for i in range(40):
            obs.event("tick", i=i)
        snap = obs.snapshot()
        assert snap["trace"]["events"] == 16
        assert snap["trace"]["dropped"] == 24
        # survivors are the newest events
        kept = [e["args"]["i"] for e in obs.trace_events()]
        assert kept == list(range(24, 40))
    finally:
        obs.configure(trace_capacity=100_000)


def test_metrics_export_jsonl_roundtrip(tmp_path):
    obs.registry().counter("journal.appends", kind="activated").inc(2)
    obs.registry().histogram("fit.seconds", backend="local").observe(1.5)
    path = obs.export_metrics(str(tmp_path / "metrics.jsonl"))
    rows = [json.loads(line) for line in open(path)]
    names = {r["name"] for r in rows}
    assert {"journal.appends", "fit.seconds"} <= names


# ---------------------------------------------------------------------------
# fit timing contract (satellite: time_total vs degree_times reconciliation)


def _assert_timing_contract(stats):
    total = stats["time_total"]
    parts = (
        stats["time_setup"]
        + stats["time_degrees"]
        + stats["time_finalize"]
        + stats["time_unattributed"]
    )
    # exact by construction (one subtraction defines the residual)
    assert total == pytest.approx(parts, abs=1e-9)
    assert stats["time_setup"] >= 0.0
    assert stats["time_degrees"] >= 0.0
    assert stats["time_finalize"] >= 0.0
    # the public per-degree list matches the unrounded accumulator up to its
    # 6-decimal rounding
    assert sum(stats["degree_times"]) == pytest.approx(
        stats["time_degrees"], abs=1e-6 * max(1, len(stats["degree_times"]))
    )


def test_fit_stats_timing_contract_local():
    X, _ = appendix_c(m=400, seed=1)
    X = MinMaxScaler(dtype="float32").fit_transform(X)
    model = oavi.fit(X, OAVIConfig(psi=0.01, engine="fast"))
    _assert_timing_contract(model.stats)


def test_fit_stats_timing_contract_streaming():
    from repro import streaming

    X, _ = appendix_c(m=600, seed=2)
    X = MinMaxScaler(dtype="float32").fit_transform(X)
    model = streaming.fit(
        streaming.ArraySource(X), OAVIConfig(psi=0.01, engine="fast"), chunk_rows=256
    )
    _assert_timing_contract(model.stats)


# ---------------------------------------------------------------------------
# journal compaction (satellite: Journal.compact)


def _fill_journal(j):
    j.append("base_fitted", version=0)
    j.append("increment", update=1)
    j.append("refit", update=1)
    j.append("activated", version=1, update=1)
    j.append("increment", update=2)
    j.append("refit", update=2)
    j.append("activated", version=2, update=2)
    j.append("increment", update=3)


def test_journal_compact_keeps_resume_state(tmp_path):
    path = str(tmp_path / "run.journal")
    with Journal(path) as j:
        _fill_journal(j)
        dropped = j.compact()
        assert dropped == 5
        kinds = [r["kind"] for r in j.replay()]
        # last activation and everything after it survive, plus the newest
        # base_fitted record the resume gate reads
        assert kinds == ["base_fitted", "activated", "increment"]
        assert j.last("activated")["version"] == 2
        assert j.last("base_fitted")["version"] == 0
        # appends continue with monotonically increasing seq
        rec = j.append("refit", update=3)
        assert rec["seq"] > j.last("activated")["seq"]

    # a fresh reader sees the compacted file as a valid journal
    with Journal(path) as j2:
        assert [r["kind"] for r in j2.replay()] == [
            "base_fitted",
            "activated",
            "increment",
            "refit",
        ]


def test_journal_compact_noop_cases(tmp_path):
    with Journal(str(tmp_path / "empty.journal")) as j:
        assert j.compact() == 0
    with Journal(str(tmp_path / "no-anchor.journal")) as j:
        j.append("base_fitted", version=0)
        j.append("increment", update=1)
        assert j.compact() == 0  # nothing to cut before: no anchor record
        assert len(j.replay()) == 2


def test_journal_compact_idempotent(tmp_path):
    with Journal(str(tmp_path / "twice.journal")) as j:
        _fill_journal(j)
        assert j.compact() > 0
        assert j.compact() == 0
        assert [r["kind"] for r in j.replay()] == [
            "base_fitted",
            "activated",
            "increment",
        ]


def test_journal_compact_preserves_crc_integrity(tmp_path):
    path = str(tmp_path / "crc.journal")
    with Journal(path) as j:
        _fill_journal(j)
        j.compact()
    # every surviving line still carries a valid CRC
    with Journal(path) as j2:
        for rec in j2.replay():
            assert rec["crc"]


def test_journal_compact_counts_metric(tmp_path):
    before = obs.registry().counter("journal.appends", kind="activated").value
    with Journal(str(tmp_path / "m.journal")) as j:
        _fill_journal(j)
    after = obs.registry().counter("journal.appends", kind="activated").value
    assert after - before == 2
