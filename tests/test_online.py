"""Incremental OAVI (repro.online) + the continuous serving loop.

The load-bearing properties:

* **fold commutativity**: ``update(update(S, a), b)`` is bit-identical to
  ``update(S, a ++ b)`` and to a one-shot streaming fit on the concatenated
  data — for fast and oracle engines, across chunk sizes, at arbitrary
  (non-block-aligned) increment sizes;
* **zero warm recompiles**: an update after any warm streaming fit of the
  same config compiles nothing (shared accumulator/stats-step caches);
* **border growth**: new data that flips an accept/reject decision replays
  only the affected degrees, and the result still matches the one-shot fit;
* FitState survives a save -> load round trip mid-sequence;
* shard directories grow in place (append + refresh) and partial writes
  fail loudly instead of serving truncated data;
* host->device prefetch changes nothing but the wall clock;
* the serving registry's hot-swap is atomic under reader/writer churn, and
  ``launch/continuous_vi.py`` serves bit-correct responses while updates
  are in flight.
"""

import threading

import numpy as np
import pytest

from repro import api, streaming
from repro.core.oavi import OAVIConfig
from repro.data.synthetic import planted_source, random_cube, write_shards
from repro import online
from repro.online import DriftConfig, DriftMonitor, FitState
from repro.streaming import ArraySource, ScaledSource, ShardDirSource
from repro.streaming.fit import prefetch_map
from repro.streaming.scaler import StreamingMinMaxScaler

M_BASE = 2500
M_MID = 3211  # deliberately NOT a multiple of GRAM_BLOCK or chunk_rows
M_FULL = 3900


def _assert_models_bit_equal(a, b):
    assert a.book.terms == b.book.terms
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs), ga.term
        assert ga.mse == gb.mse


@pytest.fixture(scope="module")
def stream():
    """Prefix-consistent planted stream: ``planted_source`` is
    tile-deterministic, so the m-row source is literally the first m rows of
    the larger one — exactly the grown-source contract update() assumes.
    Seed 3's per-feature variance ranking is stable from 2500 to 3900 rows,
    so growth does not flip the Pearson order (fold-count assertions depend
    on that; bit-identity holds either way)."""
    scaler = StreamingMinMaxScaler(dtype="float32").fit_source(
        planted_source(M_FULL, n=3, seed=3), 1024
    )
    view = lambda m: ScaledSource(planted_source(m, n=3, seed=3), scaler)  # noqa: E731
    return view, scaler


CFG = OAVIConfig(psi=0.005, engine="fast", ordering="pearson", cap_terms=64)


# ---------------------------------------------------------------------------
# fold commutativity / bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk_rows", [512, 1024, 2048])
def test_update_bit_identical_to_one_shot_fast(stream, chunk_rows):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=chunk_rows)
    res = online.update(model0, state0, view(M_FULL))
    ref = streaming.fit(view(M_FULL), CFG, chunk_rows=chunk_rows)
    _assert_models_bit_equal(res.model, ref)
    assert np.array_equal(res.model.feature_perm, ref.feature_perm)


def test_update_chain_commutes_with_one_hop(stream):
    """update(update(S, a), b) == update(S, a ++ b) == one-shot, at
    non-aligned increment boundaries."""
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    hop1 = online.update(model0, state0, view(M_MID))
    chained = online.update(hop1.model, hop1.state, view(M_FULL))
    one_hop = online.update(model0, state0, view(M_FULL))
    ref = streaming.fit(view(M_FULL), CFG, chunk_rows=512)
    _assert_models_bit_equal(chained.model, ref)
    _assert_models_bit_equal(one_hop.model, ref)
    # the two paths also agree on the *state* they hand to the next update
    for ra, rb in zip(chained.state.records, one_hop.state.records):
        assert (ra.degree, ra.ell, ra.K, ra.Lcap, ra.Kcap) == (
            rb.degree, rb.ell, rb.K, rb.Lcap, rb.Kcap)
        assert np.array_equal(ra.accQL, rb.accQL)
        assert np.array_equal(ra.accC, rb.accC)


def test_update_bit_identical_oracle_engine(stream):
    view, _ = stream
    cfg = OAVIConfig(psi=0.005, engine="oracle", ihb=True, ordering="none",
                     cap_terms=64)
    model0, state0 = online.fit(view(M_BASE), cfg, chunk_rows=512)
    res = online.update(model0, state0, view(M_FULL))
    ref = streaming.fit(view(M_FULL), cfg, chunk_rows=512)
    _assert_models_bit_equal(res.model, ref)


def test_update_zero_recompiles_warm(stream):
    view, _ = stream
    streaming.fit(view(M_FULL), CFG, chunk_rows=512)  # warm the caches
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    assert model0.stats["recompiles"] == 0
    res = online.update(model0, state0, view(M_FULL))
    assert res.stats["recompiles"] == 0
    assert res.stats["folded_degrees"] > 0


def test_update_folds_unchanged_degrees(stream):
    """Planted data growing with more of the same: the decision history is
    stable, so every degree folds and none replays."""
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    res = online.update(model0, state0, view(M_FULL))
    assert res.stats["replayed_degrees"] == []
    assert res.stats["folded_degrees"] == len(state0.records)
    assert res.stats["refit_reason"] is None
    # the fold only touched new rows: chunks ~ new_rows/chunk_rows per degree,
    # nowhere near a full m-row pass per degree
    full_chunks = -(-M_FULL // 512) * len(res.state.records)
    assert res.stats["chunks"] < full_chunks


def test_update_replays_on_border_change():
    """New data that flips an accept/reject decision (x0 vanished on the base
    rows, varies on the appended ones) replays only the degrees past the
    flip — earlier degrees keep folding — and the result still matches the
    one-shot fit on the concatenated data."""
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    rng = np.random.default_rng(0)
    base = rng.uniform(0, 1, (2560, 3)).astype(np.float32)
    base[:, 0] = 0.5 + rng.normal(0, 0.01, 2560).astype(np.float32)
    grown = np.concatenate(
        [base, rng.uniform(0, 1, (1280, 3)).astype(np.float32)], axis=0
    )
    model0, state0 = online.fit(ArraySource(base), cfg, chunk_rows=512)
    res = online.update(model0, state0, ArraySource(grown))
    ref = streaming.fit(ArraySource(grown), cfg, chunk_rows=512)
    _assert_models_bit_equal(res.model, ref)
    assert res.stats["replayed_degrees"], "expected the new data to flip a degree"
    assert res.stats["folded_degrees"] > 0  # degrees before the flip still fold
    assert res.stats["refit_reason"] is None


def test_update_perm_change_drops_records(stream):
    """A feature-order flip relabels the book's columns: no record survives,
    the update degrades to a full replay — and still matches one-shot."""
    view, _ = stream
    base = np.asarray(view(2560).read(0, 2560))
    # appended rows reverse the per-feature variance ranking
    extra = np.zeros((1280, 3), np.float32)
    extra[:, 0] = 0.5
    extra[:, 2] = np.linspace(0, 1, 1280, dtype=np.float32)
    grown = np.concatenate([base, extra], axis=0)
    model0, state0 = online.fit(ArraySource(base), CFG, chunk_rows=512)
    res = online.update(model0, state0, ArraySource(grown))
    ref = streaming.fit(ArraySource(grown), CFG, chunk_rows=512)
    if res.stats["refit_reason"] == "feature_order_changed":
        assert res.stats["folded_degrees"] == 0
    _assert_models_bit_equal(res.model, ref)


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------


def test_update_rejects_shrunk_source(stream):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    with pytest.raises(ValueError, match="shrank"):
        online.update(model0, state0, view(M_BASE - 512))


def test_update_rejects_changed_prefix(stream):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    tampered = np.asarray(view(M_FULL).read(0, M_FULL)).copy()
    tampered[0, 0] += 0.25  # a row the state already accumulated
    with pytest.raises(ValueError, match="prefix mismatch"):
        online.update(model0, state0, ArraySource(tampered))


def test_update_rejects_foreign_model(stream):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    other, _ = online.fit(
        view(M_BASE), OAVIConfig(psi=0.5, engine="fast", cap_terms=64),
        chunk_rows=512,
    )
    if other.book.terms != model0.book.terms:
        with pytest.raises(ValueError, match="does not belong"):
            online.update(other, state0, view(M_FULL))


def test_update_rejects_feature_mismatch(stream):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    with pytest.raises(ValueError, match="features"):
        online.update(model0, state0, ArraySource(np.zeros((4000, 5), np.float32)))


# ---------------------------------------------------------------------------
# FitState serialization
# ---------------------------------------------------------------------------


def test_fit_state_save_load_update_round_trip(stream, tmp_path):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    state0.save(str(tmp_path / "state"))
    loaded = FitState.load(str(tmp_path / "state"))
    assert loaded.num_rows == state0.num_rows
    assert loaded.aligned_rows == state0.aligned_rows
    assert loaded.config == state0.config
    assert np.array_equal(loaded.book_parents, state0.book_parents)
    assert np.array_equal(loaded.moments[0], state0.moments[0])
    assert loaded.moment_rows == state0.moment_rows
    for ra, rb in zip(loaded.records, state0.records):
        assert np.array_equal(ra.accQL, rb.accQL)
        assert np.array_equal(ra.accC, rb.accC)
    res = online.update(model0, loaded, view(M_FULL))
    ref = streaming.fit(view(M_FULL), CFG, chunk_rows=512)
    _assert_models_bit_equal(res.model, ref)


def test_fit_state_format_tag_enforced(stream, tmp_path):
    view, _ = stream
    _, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    state0.save(str(tmp_path / "state"))
    with pytest.raises(ValueError, match="format"):
        api.load_state_dict(str(tmp_path / "state"), "repro.some_other_format.v1")


# ---------------------------------------------------------------------------
# api / pipeline wiring
# ---------------------------------------------------------------------------


def test_api_capture_state_and_update(stream):
    view, _ = stream
    model = api.fit(view(M_BASE), "oavi:fast", psi=0.005, chunk_rows=512,
                    capture_state=True, ordering="pearson", cap_terms=64)
    assert isinstance(model.fit_state, FitState)
    assert model.stats["api"]["online"] is True
    res = api.update(model, model.fit_state, view(M_FULL))
    ref = streaming.fit(
        view(M_FULL),
        OAVIConfig(psi=0.005, engine="fast", ordering="pearson", cap_terms=64),
        chunk_rows=512,
    )
    _assert_models_bit_equal(res.model, ref)
    assert isinstance(res.model.fit_state, FitState)
    assert res.model.stats["api"]["online"] is True


def test_api_capture_state_requires_streaming():
    X = random_cube(512, 3, seed=0)
    with pytest.raises(ValueError, match="capture_state"):
        api.fit(X, "oavi:fast", capture_state=True)


def test_pipeline_capture_fit_state():
    from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier

    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (1200, 3)).astype(np.float32)
    y = (X[:, 0] > 0.5).astype(int)
    clf = VanishingIdealClassifier(PipelineConfig(
        method="oavi:fast", psi=0.01, chunk_rows=512, capture_fit_state=True,
        oavi_kw={"cap_terms": 64, "max_degree": 3},
    ))
    clf.fit(X, y)
    assert len(clf.fit_states) == len(clf.models) == 2
    for c, m, s in zip(clf.classes_, clf.models, clf.fit_states):
        assert s.num_rows == int(np.sum(y == c))
        assert np.array_equal(np.asarray(m.book.parents, np.int32), s.book_parents)


def test_pipeline_capture_fit_state_requires_chunk_rows():
    from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier

    clf = VanishingIdealClassifier(PipelineConfig(capture_fit_state=True))
    with pytest.raises(ValueError, match="chunk_rows"):
        clf.fit(np.zeros((64, 3), np.float32), np.zeros(64, int))


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------


def test_drift_quiet_on_same_distribution(stream):
    view, _ = stream
    _, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    mon = DriftMonitor.from_fit_state(state0)
    mon.observe(np.asarray(view(M_FULL).read(M_BASE, M_FULL)))
    trig, sig = mon.should_refit()
    assert not trig and sig["triggered"] == []


def test_drift_triggers_on_mean_shift(stream):
    view, _ = stream
    _, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    mon = DriftMonitor.from_fit_state(state0)
    shifted = np.asarray(view(M_FULL).read(M_BASE, M_FULL)) * 0.9 + 0.4
    mon.observe(shifted)
    trig, sig = mon.should_refit()
    assert trig and "mean_shift" in sig["triggered"]
    assert sig["oob_frac"] > 0  # shifted values escape the frozen [0,1] box


def test_drift_min_rows_gate(stream):
    view, _ = stream
    _, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    mon = DriftMonitor.from_fit_state(state0, DriftConfig(min_rows=512))
    mon.observe(np.full((100, 3), 5.0, np.float32))  # wildly off, but tiny
    assert not mon.should_refit()[0]
    mon.observe(np.full((412, 3), 5.0, np.float32))
    assert mon.should_refit()[0]


def test_drift_rebase_absorbs_window(stream):
    view, _ = stream
    _, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    mon = DriftMonitor.from_fit_state(state0)
    mon.observe(np.asarray(view(M_FULL).read(M_BASE, M_FULL)))
    assert mon.window_rows == M_FULL - M_BASE
    mon.rebase()
    assert mon.window_rows == 0
    assert mon.signals()["mean_shift"] == 0.0  # empty window: quiet


def test_drift_requires_moments():
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    _, state = online.fit(
        ArraySource(random_cube(512, 3, seed=1)), cfg, chunk_rows=512
    )
    with pytest.raises(ValueError, match="moment"):
        DriftMonitor.from_fit_state(state)


# ---------------------------------------------------------------------------
# shard growth (append / refresh / partial writes)
# ---------------------------------------------------------------------------


def test_shard_append_refresh_round_trip(tmp_path):
    d = str(tmp_path / "shards")
    a = random_cube(1024, 3, seed=0)
    b = random_cube(512, 3, seed=1)
    write_shards(d, a, shard_rows=512)
    src = ShardDirSource(d)
    assert src.num_rows == 1024
    write_shards(d, b, append=True)
    assert src.num_rows == 1024  # invisible until refresh: reads stay stable
    assert src.refresh() == 512
    assert src.num_rows == 1536
    assert np.array_equal(src.read(0, 1536), np.concatenate([a, b]))
    assert src.refresh() == 0


def test_shard_append_rejects_partial_trailing_shard(tmp_path):
    d = str(tmp_path / "shards")
    write_shards(d, random_cube(700, 3, seed=0), shard_rows=512)  # 700 % 512 != 0
    with pytest.raises(ValueError, match="multiple of shard_rows"):
        write_shards(d, random_cube(512, 3, seed=1), append=True)


def test_shard_append_rejects_schema_mismatch(tmp_path):
    d = str(tmp_path / "shards")
    write_shards(d, random_cube(512, 3, seed=0), shard_rows=512)
    with pytest.raises(ValueError, match="append mismatch"):
        write_shards(d, random_cube(512, 4, seed=1), append=True)


def test_shard_partial_write_detected(tmp_path):
    import json
    import os

    d = str(tmp_path / "shards")
    write_shards(d, random_cube(1024, 3, seed=0), shard_rows=512)
    # meta promising a shard that never landed = torn write
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    meta["num_rows"], meta["num_shards"] = 2048, 4
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="partial write"):
        ShardDirSource(d)


def test_shard_refresh_rejects_shrink(tmp_path):
    import json
    import os

    d = str(tmp_path / "shards")
    write_shards(d, random_cube(1024, 3, seed=0), shard_rows=512)
    src = ShardDirSource(d)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    meta["num_rows"], meta["num_shards"] = 512, 1
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="shrink"):
        src.refresh()


def test_online_update_over_growing_shard_dir(tmp_path):
    """The integration the continuous loop runs on: append, refresh, update
    — bit-identical to the one-shot fit on everything."""
    d = str(tmp_path / "shards")
    base = np.asarray(planted_source(2560, n=3, seed=2).read(0, 2560))
    more = np.asarray(planted_source(3584, n=3, seed=2).read(2560, 3584))
    write_shards(d, base, shard_rows=512)
    raw = ShardDirSource(d)
    scaler = StreamingMinMaxScaler(dtype="float32").fit(base)
    src = ScaledSource(raw, scaler)
    model0, state0 = online.fit(src, CFG, chunk_rows=512)
    write_shards(d, more, append=True)
    assert raw.refresh() == 1024
    res = online.update(model0, state0, src)
    ref = streaming.fit(
        ScaledSource(ArraySource(np.concatenate([base, more])), scaler),
        CFG, chunk_rows=512,
    )
    _assert_models_bit_equal(res.model, ref)


# ---------------------------------------------------------------------------
# prefetch
# ---------------------------------------------------------------------------


def test_prefetch_map_preserves_order_and_laziness():
    staged = []
    out = list(prefetch_map(lambda i: staged.append(i) or i * i, range(6)))
    assert out == [0, 1, 4, 9, 16, 25]
    assert staged == list(range(6))
    assert list(prefetch_map(lambda i: i, [])) == []
    assert list(prefetch_map(lambda i: i, [7], enabled=False)) == [7]


def test_streaming_fit_prefetch_bit_identical(stream):
    view, _ = stream
    on = streaming.fit(view(M_BASE), CFG, chunk_rows=512, prefetch=True)
    off = streaming.fit(view(M_BASE), CFG, chunk_rows=512, prefetch=False)
    _assert_models_bit_equal(on, off)


def test_online_update_prefetch_bit_identical(stream):
    view, _ = stream
    model0, state0 = online.fit(view(M_BASE), CFG, chunk_rows=512)
    a = online.update(model0, state0, view(M_FULL), prefetch=True)
    b = online.update(model0, state0, view(M_FULL), prefetch=False)
    _assert_models_bit_equal(a.model, b.model)


# ---------------------------------------------------------------------------
# registry hot-swap atomicity
# ---------------------------------------------------------------------------


def test_registry_hot_swap_atomic_under_churn():
    """Readers hammering the registry during register/activate/remove churn
    never observe a half-registered model: every resolved entry is fully
    warmed, was explicitly activated, and transforms to exactly its own
    version's expected output."""
    from repro.serving import EngineConfig, ModelRegistry

    X = random_cube(600, 3, seed=0)
    model = api.fit(X, "oavi:fast", psi=0.01, backend="local", cap_terms=64)
    probe = X[:40]
    expected = {}

    reg = ModelRegistry(engine_config=EngineConfig(min_bucket=32, max_bucket=128))
    first = reg.register("vi", model, activate=False)
    expected[first.version] = first.transform(probe, scaled=True)
    reg.activate("vi", first.version)

    stop = threading.Event()
    failures = []

    def reader():
        while not stop.is_set():
            try:
                entry = reg.get("vi")  # active version, whatever it is now
            except KeyError as e:  # an active pointer must always exist here
                failures.append(e)
                return
            if not entry.ever_activated or entry.engine is None:
                failures.append(AssertionError(
                    f"resolved un-activated/unwarmed v{entry.version}"))
                return
            out = entry.transform(probe, scaled=True)
            if not np.array_equal(out, expected[entry.version]):
                failures.append(AssertionError(
                    f"v{entry.version} served foreign bits"))
                return

    readers = [threading.Thread(target=reader) for _ in range(4)]
    for t in readers:
        t.start()
    try:
        for _ in range(25):  # writer: stage -> check -> swap -> retire old
            staged = reg.register("vi", model, activate=False)
            expected[staged.version] = staged.transform(probe, scaled=True)
            assert reg.active_version("vi") != staged.version  # staging is dark
            reg.activate("vi", staged.version)
            for v in reg.versions("vi")[:-1]:
                reg.remove("vi", v)
    finally:
        stop.set()
        for t in readers:
            t.join()
    assert not failures, failures[0]
    assert reg.versions("vi") == (26,)


# ---------------------------------------------------------------------------
# the loop, end to end (in process)
# ---------------------------------------------------------------------------


def test_continuous_vi_serves_bit_correct_during_refit(tmp_path):
    from repro.launch import continuous_vi

    report = continuous_vi.main([
        "--base-rows", "2048", "--increments", "3", "--increment-rows", "512",
        "--shard-rows", "512", "--chunk-rows", "512", "--min-update-rows",
        "1024", "--serve-threads", "2", "--workdir", str(tmp_path),
    ])
    assert report["serve"]["mismatches"] == 0
    assert report["serve"]["requests"] > 0
    assert report["warm_recompiles"] == 0
    assert len(report["updates"]) >= 1
    assert report["versions_activated"] == 1 + len(report["updates"])
    assert len(report["staleness_s"]) == 3  # every arrival reached serving
    assert all(s > 0 for s in report["staleness_s"])
    assert report["serve"]["during_update_requests"] > 0  # true overlap


def test_continuous_vi_drift_gate_triggers(tmp_path):
    from repro.launch import continuous_vi

    report = continuous_vi.main([
        "--base-rows", "2048", "--increments", "2", "--increment-rows", "512",
        "--shard-rows", "512", "--chunk-rows", "512", "--min-update-rows",
        "99999", "--drift-at-increment", "0", "--serve-threads", "1",
        "--workdir", str(tmp_path),
    ])
    assert any(u["drift"]["triggered"] for u in report["updates"])
    assert report["serve"]["mismatches"] == 0
