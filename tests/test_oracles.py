"""Solver tests: AGD / CG / PCG / BPCG on OAVI's quadratic (CCOP) problems."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.oracles import (
    OracleConfig,
    quad_f,
    solve_agd,
    solve_bpcg,
    solve_cg,
    solve_pcg,
)


def _problem(seed, m=200, ell=6, Lcap=8):
    rng = np.random.default_rng(seed)
    A = rng.uniform(0, 1, (m, ell)).astype(np.float32)
    b = rng.uniform(0, 1, m).astype(np.float32)
    Q = np.zeros((Lcap, Lcap), np.float32)
    q = np.zeros((Lcap,), np.float32)
    Q[:ell, :ell] = A.T @ A / m
    q[:ell] = A.T @ b / m
    btb = np.float32(b @ b / m)
    mask = np.arange(Lcap) < ell
    y_star = -np.linalg.solve(Q[:ell, :ell] + 1e-9 * np.eye(ell), q[:ell])
    f_star = (y_star @ Q[:ell, :ell] @ y_star + 2 * q[:ell] @ y_star + btb)
    return Q, q, btb, mask, y_star, f_star


CFG = {
    "agd": OracleConfig(name="agd", max_iter=5000, eps_frac=1e-3),
    "cg": OracleConfig(name="cg", max_iter=5000, eps_frac=1e-3, tau=1000.0),
    "pcg": OracleConfig(name="pcg", max_iter=5000, eps_frac=1e-3, tau=1000.0),
    "bpcg": OracleConfig(name="bpcg", max_iter=5000, eps_frac=1e-3, tau=1000.0),
}
SOLVERS = {"agd": solve_agd, "cg": solve_cg, "pcg": solve_pcg, "bpcg": solve_bpcg}


@pytest.mark.parametrize("name", ["agd", "cg", "pcg", "bpcg"])
def test_solver_reaches_near_optimum(name):
    Q, q, btb, mask, y_star, f_star = _problem(0)
    psi = jnp.asarray(0.005, jnp.float32)
    res = SOLVERS[name](
        jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
        jnp.asarray(mask), psi, CFG[name], None,
    )
    # solvers may stop early once f <= psi (paper's early termination);
    # otherwise they must be near f*
    f = float(res.f)
    assert f <= max(float(f_star) + 5e-3, 0.005 + 1e-6)


@pytest.mark.parametrize("name", ["cg", "pcg", "bpcg"])
def test_fw_iterates_stay_in_l1_ball(name):
    Q, q, btb, mask, *_ = _problem(1)
    cfg = OracleConfig(name=name, max_iter=300, eps_frac=1e-4, tau=2.0)
    res = SOLVERS[name](
        jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
        jnp.asarray(mask), jnp.asarray(1e-9, jnp.float32), cfg, None,
    )
    assert float(jnp.sum(jnp.abs(res.y))) <= cfg.tau - 1.0 + 1e-4


def test_warm_start_reduces_iterations():
    """IHB's premise: starting at the closed-form optimum needs ~no iters."""
    Q, q, btb, mask, y_star, f_star = _problem(2)
    psi = jnp.asarray(1e-9, jnp.float32)
    cfg = CFG["cg"]
    warm = np.zeros(Q.shape[0], np.float32)
    warm[: len(y_star)] = y_star
    cold = solve_cg(jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb),
                    jnp.asarray(1.0), jnp.asarray(mask), psi, cfg, None)
    hot = solve_cg(jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb),
                   jnp.asarray(1.0), jnp.asarray(mask), psi, cfg,
                   jnp.asarray(warm))
    assert int(hot.iters) <= int(cold.iters)
    assert int(hot.iters) <= 2


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_solvers_agree_near_optimum(seed):
    Q, q, btb, mask, y_star, f_star = _problem(seed, m=100, ell=4, Lcap=4)
    psi = jnp.asarray(1e-12, jnp.float32)  # force full optimization
    fs = []
    for name in ["agd", "bpcg"]:
        res = SOLVERS[name](
            jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
            jnp.asarray(np.ones(4, bool)), psi, CFG[name], None,
        )
        fs.append(float(res.f))
    assert abs(fs[0] - fs[1]) < 5e-3
    assert min(fs) >= float(f_star) - 5e-3  # cannot beat the true optimum
