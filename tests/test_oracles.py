"""Solver tests: AGD / CG / PCG / BPCG on OAVI's quadratic (CCOP) problems.

The fixed-schedule twins (``solve_*_scheduled``) are tested for *bitwise*
parity against the while_loop refs: both disciplines run the same
cond/body/finish closures, so at a sufficient budget every field of the
result must be identical, and under ``vmap`` each lane must reproduce its
single-solve bits exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.oracles import (
    SCHEDULED_SOLVERS,
    SOLVERS as ORACLE_SOLVERS,
    OracleConfig,
    escalate_schedule,
    max_schedule,
    quad_f,
    schedule_budget,
    solve_agd,
    solve_bpcg,
    solve_cg,
    solve_pcg,
)


def _problem(seed, m=200, ell=6, Lcap=8):
    rng = np.random.default_rng(seed)
    A = rng.uniform(0, 1, (m, ell)).astype(np.float32)
    b = rng.uniform(0, 1, m).astype(np.float32)
    Q = np.zeros((Lcap, Lcap), np.float32)
    q = np.zeros((Lcap,), np.float32)
    Q[:ell, :ell] = A.T @ A / m
    q[:ell] = A.T @ b / m
    btb = np.float32(b @ b / m)
    mask = np.arange(Lcap) < ell
    y_star = -np.linalg.solve(Q[:ell, :ell] + 1e-9 * np.eye(ell), q[:ell])
    f_star = (y_star @ Q[:ell, :ell] @ y_star + 2 * q[:ell] @ y_star + btb)
    return Q, q, btb, mask, y_star, f_star


CFG = {
    "agd": OracleConfig(name="agd", max_iter=5000, eps_frac=1e-3),
    "cg": OracleConfig(name="cg", max_iter=5000, eps_frac=1e-3, tau=1000.0),
    "pcg": OracleConfig(name="pcg", max_iter=5000, eps_frac=1e-3, tau=1000.0),
    "bpcg": OracleConfig(name="bpcg", max_iter=5000, eps_frac=1e-3, tau=1000.0),
}
SOLVERS = {"agd": solve_agd, "cg": solve_cg, "pcg": solve_pcg, "bpcg": solve_bpcg}


@pytest.mark.parametrize("name", ["agd", "cg", "pcg", "bpcg"])
def test_solver_reaches_near_optimum(name):
    Q, q, btb, mask, y_star, f_star = _problem(0)
    psi = jnp.asarray(0.005, jnp.float32)
    res = SOLVERS[name](
        jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
        jnp.asarray(mask), psi, CFG[name], None,
    )
    # solvers may stop early once f <= psi (paper's early termination);
    # otherwise they must be near f*
    f = float(res.f)
    assert f <= max(float(f_star) + 5e-3, 0.005 + 1e-6)


@pytest.mark.parametrize("name", ["cg", "pcg", "bpcg"])
def test_fw_iterates_stay_in_l1_ball(name):
    Q, q, btb, mask, *_ = _problem(1)
    cfg = OracleConfig(name=name, max_iter=300, eps_frac=1e-4, tau=2.0)
    res = SOLVERS[name](
        jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
        jnp.asarray(mask), jnp.asarray(1e-9, jnp.float32), cfg, None,
    )
    assert float(jnp.sum(jnp.abs(res.y))) <= cfg.tau - 1.0 + 1e-4


def test_warm_start_reduces_iterations():
    """IHB's premise: starting at the closed-form optimum needs ~no iters."""
    Q, q, btb, mask, y_star, f_star = _problem(2)
    psi = jnp.asarray(1e-9, jnp.float32)
    cfg = CFG["cg"]
    warm = np.zeros(Q.shape[0], np.float32)
    warm[: len(y_star)] = y_star
    cold = solve_cg(jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb),
                    jnp.asarray(1.0), jnp.asarray(mask), psi, cfg, None)
    hot = solve_cg(jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb),
                   jnp.asarray(1.0), jnp.asarray(mask), psi, cfg,
                   jnp.asarray(warm))
    assert int(hot.iters) <= int(cold.iters)
    assert int(hot.iters) <= 2


ALL_NAMES = ["agd", "cg", "pcg", "bpcg"]


def _assert_same_result(ref, sch, *, name=""):
    assert np.array_equal(np.asarray(ref.y), np.asarray(sch.y)), f"{name}: y"
    assert np.asarray(ref.f) == np.asarray(sch.f), f"{name}: f"
    assert np.asarray(ref.gap) == np.asarray(sch.gap), f"{name}: gap"
    assert int(ref.iters) == int(sch.iters), f"{name}: iters"


def _solve_args(seed, **pkw):
    Q, q, btb, mask, *_ = _problem(seed, **pkw)
    return (
        jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
        jnp.asarray(mask),
    )


@pytest.mark.parametrize("name", ALL_NAMES)
def test_scheduled_full_budget_parity(name):
    """At the max schedule, the fixed-schedule twin is bit-identical to the
    while_loop ref on every result field (shared cond/body/finish)."""
    args = _solve_args(3)
    psi = jnp.asarray(1e-6, jnp.float32)  # force real iterations
    cfg = CFG[name]
    ref = ORACLE_SOLVERS[name](*args, psi, cfg, None)
    sch = SCHEDULED_SOLVERS[name](*args, psi, cfg, None,
                                  schedule=max_schedule(cfg))
    assert bool(sch.converged)
    _assert_same_result(ref, sch, name=name)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_scheduled_escalation_reaches_while_ref(name):
    """Escalating an undersized budget (x2 until converged) lands bitwise on
    the while_loop result: iteration chunks compose exactly, so the longer
    run replays the shorter one's iterations and continues."""
    args = _solve_args(4)
    psi = jnp.asarray(1e-7, jnp.float32)
    cfg = CFG[name]
    ref = ORACLE_SOLVERS[name](*args, psi, cfg, None)
    schedule, escalations = 1, 0
    while True:
        sch = SCHEDULED_SOLVERS[name](*args, psi, cfg, None, schedule=schedule)
        if bool(sch.converged) or schedule >= max_schedule(cfg):
            break
        schedule = escalate_schedule(cfg, schedule)
        escalations += 1
    assert bool(sch.converged)
    assert escalations >= 1, "problem too easy to exercise escalation"
    _assert_same_result(ref, sch, name=name)


@pytest.mark.parametrize("name", ["cg", "pcg", "bpcg"])
def test_scheduled_budget_zero_warm_certificate(name):
    """Budget 0 = certificate check only: a warm start at the solution makes
    the entry-gap certificates fire without a single iteration, matching the
    while ref (which also exits at its first cond evaluation)."""
    Q, q, btb, mask, y_star, f_star = _problem(5)
    warm = np.zeros(Q.shape[0], np.float32)
    warm[: len(y_star)] = y_star
    args = (jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
            jnp.asarray(mask))
    psi = jnp.asarray(float(f_star) + 1e-3, jnp.float32)  # warm start vanishes
    cfg = CFG[name]
    ref = ORACLE_SOLVERS[name](*args, psi, cfg, jnp.asarray(warm))
    sch = SCHEDULED_SOLVERS[name](*args, psi, cfg, jnp.asarray(warm), schedule=0)
    assert bool(sch.converged)
    assert int(sch.iters) == 0
    _assert_same_result(ref, sch, name=name)


def test_schedule_budget_is_config_only():
    assert schedule_budget(OracleConfig(schedule=0)) == 0
    assert schedule_budget(OracleConfig(schedule=3)) == 4
    assert schedule_budget(OracleConfig(schedule=64, max_iter=16)) == 16
    assert escalate_schedule(OracleConfig(), 0) == 1
    assert escalate_schedule(OracleConfig(), 4) == 8
    assert escalate_schedule(OracleConfig(max_iter=16), 16) == 16


@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 10_000),
    st.integers(2, 7),
    st.sampled_from(ALL_NAMES),
    st.sampled_from([2.0, 10.0, 1000.0]),
    st.sampled_from([1e-7, 1e-3, 0.05]),
)
def test_property_scheduled_matches_while(seed, ell, name, tau, psi_val):
    """Hypothesis sweep over problems, masks, radii and accuracy targets:
    the fixed-schedule twin at full budget is always bitwise the while ref."""
    args = _solve_args(seed, m=80, ell=ell, Lcap=8)
    cfg = OracleConfig(name=name, max_iter=512, eps_frac=1e-3, tau=tau)
    psi = jnp.asarray(psi_val, jnp.float32)
    ref = ORACLE_SOLVERS[name](*args, psi, cfg, None)
    sch = SCHEDULED_SOLVERS[name](*args, psi, cfg, None,
                                  schedule=max_schedule(cfg))
    assert bool(sch.converged)
    _assert_same_result(ref, sch, name=f"{name} seed={seed}")


@pytest.mark.parametrize("name", ALL_NAMES)
def test_scheduled_vmap_bit_identity(name):
    """A vmapped batch of k scheduled solves is bit-identical to the k
    single solves — the contract the class-batched fit rides on."""
    k = 4
    probs = [_problem(10 + i, m=120, ell=3 + i, Lcap=8) for i in range(k)]
    Qb = jnp.stack([jnp.asarray(p[0]) for p in probs])
    qb = jnp.stack([jnp.asarray(p[1]) for p in probs])
    btbb = jnp.stack([jnp.asarray(p[2]) for p in probs])
    maskb = jnp.stack([jnp.asarray(p[3]) for p in probs])
    y0b = jnp.zeros((k, 8), jnp.float32)
    psi = jnp.asarray(1e-6, jnp.float32)
    cfg = OracleConfig(name=name, max_iter=256, eps_frac=1e-3, tau=10.0)
    schedule = max_schedule(cfg)

    def single(Q, q, btb, mask, y0):
        return SCHEDULED_SOLVERS[name](
            Q, q, btb, jnp.asarray(1.0), mask, psi, cfg, y0, schedule=schedule
        )

    batched = jax.jit(jax.vmap(single))(Qb, qb, btbb, maskb, y0b)
    for i in range(k):
        ref = single(Qb[i], qb[i], btbb[i], maskb[i], y0b[i])
        lane = jax.tree_util.tree_map(lambda a: a[i], batched)
        _assert_same_result(ref, lane, name=f"{name} lane={i}")
        assert bool(ref.converged) == bool(lane.converged)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_solvers_agree_near_optimum(seed):
    Q, q, btb, mask, y_star, f_star = _problem(seed, m=100, ell=4, Lcap=4)
    psi = jnp.asarray(1e-12, jnp.float32)  # force full optimization
    fs = []
    for name in ["agd", "bpcg"]:
        res = SOLVERS[name](
            jnp.asarray(Q), jnp.asarray(q), jnp.asarray(btb), jnp.asarray(1.0),
            jnp.asarray(np.ones(4, bool)), psi, CFG[name], None,
        )
        fs.append(float(res.f))
    assert abs(fs[0] - fs[1]) < 5e-3
    assert min(fs) >= float(f_star) - 5e-3  # cannot beat the true optimum
