"""Numerical equivalence of the §Perf optimization variants vs baselines.

Per the hillclimbing methodology, every beyond-paper optimization is a
config switch; these tests pin each variant to the baseline semantics so a
perf win can never silently change the math.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models import moe as moe_mod


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}


def test_chunked_ce_equals_plain():
    cfg = configs.get_reduced("qwen3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    plain = float(M.loss_fn(params, batch, cfg))
    chunked = float(M.loss_fn(
        params, batch, dataclasses.replace(cfg, ce_impl="chunked", ce_chunk=64)))
    assert abs(plain - chunked) < 1e-4
    # gradients agree too
    g1 = jax.grad(M.loss_fn)(params, batch, cfg)
    g2 = jax.grad(M.loss_fn)(
        params, batch, dataclasses.replace(cfg, ce_impl="chunked", ce_chunk=64))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)


def test_chunked_attention_equals_reference():
    cfg = configs.get_reduced("qwen3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, S=64)
    ref = float(M.loss_fn(params, batch, cfg))
    chk = float(M.loss_fn(
        params, batch, dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=16)))
    assert abs(ref - chk) < 1e-4


def test_chunked_attention_equals_reference_mla():
    cfg = configs.get_reduced("deepseek-v2-lite-16b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, S=64)
    ref = float(M.loss_fn(params, batch, cfg))
    chk = float(M.loss_fn(
        params, batch, dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=16)))
    assert abs(ref - chk) < 1e-4


def test_rowwise_moe_equals_global_single_device():
    """rows=1 on a single device: rowwise dispatch must match global."""
    cfg = configs.get_reduced("kimi-k2-1t-a32b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    base = float(M.loss_fn(params, batch, cfg))
    row = float(M.loss_fn(
        params, batch,
        dataclasses.replace(cfg, moe=cfg.moe._replace(dispatch="rowwise"))))
    assert abs(base - row) < 1e-4


def test_remat_policies_same_loss_different_none():
    cfg = configs.get_reduced("qwen3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    losses = []
    for remat, policy in [(True, "full"), (True, "dots"), (False, "full")]:
        c = dataclasses.replace(cfg, remat=remat, remat_policy=policy)
        losses.append(float(M.loss_fn(params, batch, c)))
        g = jax.grad(M.loss_fn)(params, batch, c)
        assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    assert max(losses) - min(losses) < 1e-5
