"""End-to-end Algorithm 2 pipeline tests (classification quality + structure)."""

import numpy as np
import pytest

from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.core.svm import LinearSVM, LinearSVMConfig, PolySVM, PolySVMConfig


# thresholds mirror Table 3's ordering: IHB variants strongest; WIHB/ABM/VCA
# trade accuracy for sparsity / spurious vanishing (still far above chance)
_MIN_ACC = {"fast": 0.85, "cgavi-ihb": 0.85, "bpcgavi-wihb": 0.6, "abm": 0.7, "vca": 0.75}


@pytest.mark.parametrize("method", sorted(_MIN_ACC))
def test_pipeline_beats_chance_on_appc(appc_small, method):
    Xtr, ytr, Xte, yte = appc_small
    kw = {"cap_terms": 64} if method not in ("vca",) else {}
    clf = VanishingIdealClassifier(PipelineConfig(method=method, psi=0.005, oavi_kw=kw))
    clf.fit(Xtr, ytr)
    acc = clf.score(Xte, yte)
    assert acc > _MIN_ACC[method], f"{method}: test acc {acc}"


def test_pipeline_variants_agree_cgavi_agdavi(appc_small):
    """Table 3: CGAVI-IHB and AGDAVI-IHB produce identical outputs when the
    l1 constraint is slack (paper §6.2.2 'Similarity')."""
    Xtr, ytr, Xte, yte = appc_small
    accs = []
    for method in ["cgavi-ihb", "agdavi-ihb"]:
        clf = VanishingIdealClassifier(
            PipelineConfig(method=method, psi=0.005, oavi_kw={"cap_terms": 64}))
        clf.fit(Xtr, ytr)
        accs.append(clf.score(Xte, yte))
    assert abs(accs[0] - accs[1]) < 1e-6


def test_wihb_sparsity_table3(appc_small):
    """(SPAR): BPCGAVI-WIHB produces sparser generators than CGAVI-IHB."""
    Xtr, ytr, _, _ = appc_small
    sub = slice(0, 800)
    dense = VanishingIdealClassifier(
        PipelineConfig(method="cgavi-ihb", psi=0.005, oavi_kw={"cap_terms": 64}))
    dense.fit(Xtr[sub], ytr[sub])
    sparse = VanishingIdealClassifier(
        PipelineConfig(method="bpcgavi-wihb", psi=0.005, oavi_kw={"cap_terms": 64}))
    sparse.fit(Xtr[sub], ytr[sub])
    assert sparse.sparsity() >= dense.sparsity()


def test_transform_is_nonnegative(appc_small):
    Xtr, ytr, Xte, _ = appc_small
    clf = VanishingIdealClassifier(
        PipelineConfig(method="fast", psi=0.005, oavi_kw={"cap_terms": 64}))
    clf.fit(Xtr, ytr)
    ft = clf.transform(Xte)
    assert ft.shape[0] == Xte.shape[0]
    assert (ft >= 0).all()  # (FT) takes absolute values


def test_linear_svm_separable():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((400, 5))
    w = rng.standard_normal(5)
    y = (X @ w > 0).astype(int)
    svm = LinearSVM(LinearSVMConfig(lam=1e-5)).fit(X, y)
    assert svm.score(X, y) > 0.97


def test_linear_svm_l1_sparsity():
    """l1 penalty zeroes out nuisance features."""
    rng = np.random.default_rng(1)
    X = rng.standard_normal((500, 20))
    y = (X[:, 0] - X[:, 1] > 0).astype(int)
    strong = LinearSVM(LinearSVMConfig(lam=3e-2)).fit(X, y)
    W = strong.W
    used = np.abs(W).sum(axis=1) > 1e-6
    assert used[:2].all() and used.sum() <= 6


def test_poly_svm_learns_quadratic_boundary():
    rng = np.random.default_rng(2)
    X = rng.uniform(-1, 1, (600, 2))
    y = (X[:, 0] ** 2 + X[:, 1] ** 2 < 0.5).astype(int)
    svm = PolySVM(PolySVMConfig(degree=2, lam=1e-4, max_iter=3000)).fit(X, y)
    assert svm.score(X, y) > 0.9


def test_multiclass_one_vs_rest():
    rng = np.random.default_rng(3)
    centers = np.array([[0, 0], [3, 0], [0, 3]])
    X = np.concatenate([rng.normal(c, 0.4, (100, 2)) for c in centers])
    y = np.repeat([0, 1, 2], 100)
    svm = LinearSVM(LinearSVMConfig(lam=1e-4)).fit(X, y)
    assert svm.score(X, y) > 0.95
    assert set(svm.predict(X)) == {0, 1, 2}
