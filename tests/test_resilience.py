"""Resilience subsystem tests: integrity, journal, chaos, degraded serving,
crash recovery.

The property-style tests draw fault positions from a seeded RNG loop (and
run everywhere); the hypothesis variants widen the search when hypothesis is
installed.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.resilience import (
    Fault,
    FaultPlan,
    InjectedFault,
    IntegrityError,
    Journal,
    JournalError,
    PoisonRequestError,
    TransientEngineError,
    chaos,
    checksum_bytes,
    checksum_file,
    flip_bit,
    truncate_file,
    verify_file,
)
from repro.serving import (
    BatcherConfig,
    DeadlineExceeded,
    MicroBatcher,
    ModelRegistry,
    ShutdownError,
    TransformEngine,
)

# ---------------------------------------------------------------------------
# integrity primitives
# ---------------------------------------------------------------------------


def test_checksum_detects_every_random_bitflip(tmp_path):
    """Property (seeded): CRC32 is a linear code — any single flipped bit
    changes the checksum, wherever it lands."""
    rng = np.random.default_rng(0)
    p = str(tmp_path / "payload.bin")
    with open(p, "wb") as f:
        f.write(rng.bytes(4096))
    crc, nbytes = checksum_file(p)
    assert crc.startswith("crc32:") and nbytes == 4096
    verify_file(p, crc, nbytes)  # pristine file passes
    for _ in range(40):
        off, bit = int(rng.integers(0, 4096)), int(rng.integers(0, 8))
        flip_bit(p, off, bit)
        with pytest.raises(IntegrityError, match="checksum mismatch") as ei:
            verify_file(p, crc, nbytes)
        assert "payload.bin" in str(ei.value)  # names the bad file
        flip_bit(p, off, bit)  # restore
        verify_file(p, crc, nbytes)


@given(off=st.integers(0, 4095), bit=st.integers(0, 7))
@settings(max_examples=50, deadline=None)
def test_checksum_detects_bitflip_hypothesis(tmp_path, off, bit):
    rng = np.random.default_rng(1)
    p = str(tmp_path / "h.bin")
    with open(p, "wb") as f:
        f.write(rng.bytes(4096))
    crc, nbytes = checksum_file(p)
    flip_bit(p, off, bit)
    with pytest.raises(IntegrityError):
        verify_file(p, crc, nbytes)


def test_truncation_reported_as_truncation_not_checksum(tmp_path):
    p = str(tmp_path / "t.bin")
    with open(p, "wb") as f:
        f.write(b"x" * 1000)
    crc, nbytes = checksum_file(p)
    truncate_file(p, 400)
    with pytest.raises(IntegrityError, match="truncated or grown"):
        verify_file(p, crc, nbytes)
    os.remove(p)
    with pytest.raises(IntegrityError, match="missing"):
        verify_file(p, crc, nbytes)


def test_checksum_bytes_is_stable():
    # the serialized form is part of the on-disk format: keep it frozen
    assert checksum_bytes(b"") == "crc32:00000000"
    assert checksum_bytes(b"repro") == checksum_bytes(b"repro")
    assert checksum_bytes(b"repro") != checksum_bytes(b"repro\x00")


# ---------------------------------------------------------------------------
# checkpoint store: manifest v2 checksums + fallback
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(64, 8)).astype(np.float32),
            "b": rng.normal(size=(8,)).astype(np.float32)}


def test_store_leaf_corruption_detected_and_named(tmp_path):
    from repro.checkpoint import store

    d = str(tmp_path / "ckpt")
    store.save(d, 0, _tree())
    steps = store.committed_steps(d)
    sd = os.path.join(d, f"step_{steps[-1]:08d}")
    leaves = [n for n in sorted(os.listdir(sd)) if n.endswith(".npy")]
    victim = max((os.path.join(sd, n) for n in leaves), key=os.path.getsize)
    store.verify(d, steps[-1])  # pristine passes
    rng = np.random.default_rng(2)
    for _ in range(10):  # property: random positions, never silent
        off = int(rng.integers(0, os.path.getsize(victim)))
        bit = int(rng.integers(0, 8))
        flip_bit(victim, off, bit)
        with pytest.raises(IntegrityError) as ei:
            store.verify(d, steps[-1])
        assert os.path.basename(victim) in str(ei.value)
        with pytest.raises(IntegrityError):
            store.restore(d, steps[-1], _tree())
        flip_bit(victim, off, bit)  # restore
        store.verify(d, steps[-1])


def test_store_load_latest_falls_back_to_verifiable_step(tmp_path):
    from repro.checkpoint import store

    d = str(tmp_path / "ckpt")
    store.save(d, 0, _tree(0), metadata={"v": 0})
    store.save(d, 1, _tree(1), metadata={"v": 1})
    sd = os.path.join(d, "step_00000001")
    victim = max(
        (os.path.join(sd, n) for n in os.listdir(sd) if n.endswith(".npy")),
        key=os.path.getsize,
    )
    flip_bit(victim, 100, 2)
    assert store.latest_verifiable_step(d) == 0
    tree, meta, step = store.load_latest(d, _tree())
    assert step == 0 and meta["v"] == 0
    assert np.array_equal(tree["w"], _tree(0)["w"])
    # corrupting BOTH steps: never silent — the head error propagates
    sd0 = os.path.join(d, "step_00000000")
    victim0 = max(
        (os.path.join(sd0, n) for n in os.listdir(sd0) if n.endswith(".npy")),
        key=os.path.getsize,
    )
    flip_bit(victim0, 50, 1)
    with pytest.raises(IntegrityError):
        store.load_latest(d, _tree())


def test_store_manifest_v1_still_loads(tmp_path):
    """Pre-checksum (v1) manifests load presence-only — back compat."""
    from repro.checkpoint import store

    d = str(tmp_path / "ckpt")
    store.save(d, 0, _tree())
    sd = os.path.join(d, "step_00000000")
    mf = os.path.join(sd, "manifest.json")
    with open(mf) as f:
        manifest = json.load(f)
    manifest.pop("manifest_version", None)
    for entry in manifest["leaves"]:
        entry.pop("checksum", None)
        entry.pop("bytes", None)
    with open(mf, "w") as f:
        json.dump(manifest, f)
    store.verify(d, 0)  # presence-only: no checksums to check
    tree, _ = store.restore(d, 0, _tree())
    assert np.array_equal(tree["w"], _tree(0)["w"])


def test_async_saver_write_failure_surfaces(tmp_path):
    """Satellite: a failed background checkpoint write must NOT be silent —
    wait() (and the next save()) re-raise it, naming the failure."""
    from repro.checkpoint import store

    target = str(tmp_path / "not_a_dir")
    with open(target, "w") as f:
        f.write("a file where the checkpoint dir should go")
    saver = store.AsyncSaver()
    saver.save(target, 0, _tree())
    with pytest.raises(RuntimeError, match="does NOT exist"):
        saver.wait()
    # a good save afterwards works (error was consumed, saver is reusable)
    good = str(tmp_path / "ok")
    saver.save(good, 0, _tree())
    saver.wait()
    assert store.latest_step(good) == 0


def test_trainloop_resume_skips_corrupt_latest(tmp_path):
    """Satellite: TrainLoop.try_resume lands on the previous committed step
    when the newest one is corrupt, and counts the fallback."""
    from repro.checkpoint import store
    from repro.runtime.fault_tolerance import TrainLoop, TrainLoopConfig

    d = str(tmp_path / "ckpt")
    store.save(d, 10, {"x": np.full((32,), 10.0)})
    store.save(d, 20, {"x": np.full((32,), 20.0)})
    sd = os.path.join(d, "step_00000020")
    victim = [os.path.join(sd, n) for n in os.listdir(sd) if n.endswith(".npy")][0]
    flip_bit(victim, 64, 5)
    loop = TrainLoop(
        TrainLoopConfig(ckpt_dir=d),
        step_fn=lambda s, b: (s, {}),
        batch_fn=lambda i: None,
        state={"x": np.zeros((32,))},
    )
    assert loop.try_resume()
    assert loop.step == 10
    assert loop.integrity_fallbacks == 1
    assert np.array_equal(loop.state["x"], np.full((32,), 10.0))


# ---------------------------------------------------------------------------
# shard integrity + torn-write matrix
# ---------------------------------------------------------------------------


def _write_dir(tmp_path, name="shards", rows=256, shard_rows=64, n=4, seed=3):
    from repro.data.synthetic import write_shards

    d = str(tmp_path / name)
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (rows, n)).astype(np.float32)
    write_shards(d, X, shard_rows=shard_rows)
    return d, X


def test_shard_bitflip_detected_on_first_read(tmp_path):
    from repro.streaming.source import ShardDirSource

    d, X = _write_dir(tmp_path)
    rng = np.random.default_rng(4)
    for _ in range(8):  # property: random shard, random position
        idx = int(rng.integers(0, 4))
        victim = os.path.join(d, f"shard_{idx:05d}.npy")
        off = int(rng.integers(0, os.path.getsize(victim)))
        bit = int(rng.integers(0, 8))
        flip_bit(victim, off, bit)
        try:
            src = ShardDirSource(d)
        except IntegrityError as e:
            # flip hit the npy header: caught at open, still named
            assert f"shard_{idx:05d}.npy" in str(e)
        else:
            with pytest.raises(IntegrityError) as ei:
                src.read(idx * 64, idx * 64 + 1)
            assert f"shard_{idx:05d}.npy" in str(ei.value)
            # a clean shard still serves (lazy per-shard verification)
            other = (idx + 1) % 4
            got = src.read(other * 64, other * 64 + 4)
            assert np.array_equal(got, X[other * 64 : other * 64 + 4])
        flip_bit(victim, off, bit)  # restore for the next round
    assert ShardDirSource(d).verify_all() == 4  # pristine again


def test_shard_verification_can_be_disabled_and_is_lazy(tmp_path):
    from repro.streaming.source import ShardDirSource

    d, X = _write_dir(tmp_path)
    victim = os.path.join(d, "shard_00002.npy")
    flip_bit(victim, 300, 1)
    # rows of OTHER shards are served without paying for shard 2
    src = ShardDirSource(d)
    assert np.array_equal(src.read(0, 64), X[:64])
    # opting out serves even the corrupt shard (operator's explicit choice)
    raw = ShardDirSource(d, verify_checksums=False)
    assert raw.read(128, 192).shape == (64, 4)


def test_shard_truncation_detected(tmp_path):
    from repro.streaming.source import ShardDirSource

    d, _ = _write_dir(tmp_path)
    victim = os.path.join(d, "shard_00001.npy")
    truncate_file(victim, os.path.getsize(victim) - 17)
    with pytest.raises((IntegrityError, ValueError)) as ei:
        ShardDirSource(d).read(64, 128)
    assert "shard_00001.npy" in str(ei.value)


def test_torn_write_matrix(tmp_path):
    """Satellite: the three torn-write shapes a crash can leave behind."""
    from repro.streaming.source import ShardDirSource

    # (1) shard files newer than meta (crash between shard write and meta
    # commit): committed rows serve, orphans are invisible until the
    # re-append completes them
    d, X = _write_dir(tmp_path, "stale_meta")
    rng = np.random.default_rng(7)
    orphan = rng.uniform(0, 1, (64, 4)).astype(np.float32)
    np.save(os.path.join(d, "shard_00004.npy"), orphan)
    src = ShardDirSource(d)
    assert src.num_rows == 256  # meta is the commit point
    assert src.refresh() == 0
    assert np.array_equal(src.read(192, 256), X[192:])

    # (2) meta newer than shards (impossible under the committed write
    # order; means the directory was mangled): loud failure naming the gap
    d2, _ = _write_dir(tmp_path, "meta_ahead")
    meta_path = os.path.join(d2, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["num_rows"] = 320
    meta["num_shards"] = 5
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="missing"):
        ShardDirSource(d2)

    # (3) zero-length shard file (torn at the filesystem level)
    d3, _ = _write_dir(tmp_path, "zero_len")
    truncate_file(os.path.join(d3, "shard_00003.npy"), 0)
    with pytest.raises((IntegrityError, ValueError)) as ei:
        ShardDirSource(d3).read(192, 256)
    assert "shard_00003" in str(ei.value)


def test_fit_state_checkpoint_fallback(tmp_path):
    """A corrupted newest FitState step falls back to the previous one —
    recovery costs freshness (rows to re-fold), not correctness."""
    from repro.core.oavi import OAVIConfig
    from repro.online import FitState, fit as online_fit, update as online_update

    rng = np.random.default_rng(9)
    X1 = rng.uniform(0, 1, (512, 3)).astype(np.float32)
    X2 = rng.uniform(0, 1, (256, 3)).astype(np.float32)
    model, state = online_fit(X1, OAVIConfig(psi=0.01), chunk_rows=256)
    d = str(tmp_path / "state")
    state.save(d, step=0)
    res = online_update(model, state, np.concatenate([X1, X2]), chunk_rows=256)
    res.state.save(d, step=1)
    assert FitState.load(d).num_rows == 768  # head step loads
    sd = os.path.join(d, "step_00000001")
    victim = max(
        (os.path.join(sd, n) for n in os.listdir(sd) if n.endswith(".npy")),
        key=os.path.getsize,
    )
    flip_bit(victim, -1, 6)
    loaded = FitState.load(d)
    assert loaded.num_rows == 512  # fell back to the pre-corruption step


# ---------------------------------------------------------------------------
# journal
# ---------------------------------------------------------------------------


def test_journal_roundtrip_and_seq_resume(tmp_path):
    p = str(tmp_path / "j.jsonl")
    with Journal(p) as j:
        j.append("update_start", update=0, rows=100)
        j.append("state_saved", update=0, step=1)
    j2 = Journal(p)  # re-open: seq continues past committed records
    rec = j2.append("activated", update=0, version=2)
    assert rec["seq"] == 2
    kinds = [r["kind"] for r in j2.replay()]
    assert kinds == ["update_start", "state_saved", "activated"]
    assert j2.last("state_saved")["step"] == 1
    assert j2.last("nonexistent") is None
    j2.close()


def test_journal_torn_tail_dropped(tmp_path):
    p = str(tmp_path / "j.jsonl")
    j = Journal(p)
    for i in range(3):
        j.append("tick", i=i)
    j.close()
    # (a) half-written line, no newline — crash mid-append
    with open(p, "a") as f:
        f.write('{"seq": 3, "kind": "tick", "i"')
    assert [r["i"] for r in Journal(p).replay()] == [0, 1, 2]
    # (b) complete final line with a bad CRC — crash mid-fsync
    with open(p, "w") as f:
        pass
    j = Journal(p)
    for i in range(3):
        j.append("tick", i=i)
    j.close()
    with open(p, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    bad = lines[-1].replace(b'"crc": "crc32:', b'"crc": "crc32:f', 1)
    with open(p, "wb") as f:
        f.writelines(lines[:-1] + [bad])
    assert [r["i"] for r in Journal(p).replay()] == [0, 1]
    # appends after a torn tail keep the committed lineage intact
    j = Journal(p)
    j.append("tick", i=99)
    assert [r["i"] for r in j.replay()] == [0, 1, 99]
    j.close()


def test_journal_midhistory_corruption_raises(tmp_path):
    p = str(tmp_path / "j.jsonl")
    j = Journal(p)
    for i in range(4):
        j.append("tick", i=i)
    j.close()
    with open(p, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    lines[1] = lines[1].replace(b'"i": 1', b'"i": 7')  # committed record lies
    with open(p, "wb") as f:
        f.writelines(lines)
    with pytest.raises(JournalError, match="mid-history"):
        Journal(p).replay()


def test_journal_concurrent_appends_never_interleave(tmp_path):
    import threading

    p = str(tmp_path / "j.jsonl")
    j = Journal(p)

    def writer(tid):
        for i in range(20):
            j.append("w", tid=tid, i=i)

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    j.close()
    records = Journal(p).replay()
    assert len(records) == 80
    assert [r["seq"] for r in records] == list(range(80))


# ---------------------------------------------------------------------------
# chaos plans
# ---------------------------------------------------------------------------


def test_fault_plan_roundtrip_and_exact_occurrence(tmp_path):
    plan = FaultPlan([Fault(site="s", at=3, action="raise", times=2)])
    p = str(tmp_path / "plan.json")
    plan.save(p)
    plan2 = FaultPlan.load(p)
    for run in range(2):  # determinism: identical schedule on every run
        fresh = FaultPlan.from_json(plan2.to_json())
        fired = []
        for i in range(1, 7):
            try:
                fresh.fire("s")
                fired.append(False)
            except InjectedFault:
                fired.append(True)
        assert fired == [False, False, True, True, False, False]


def test_fire_is_noop_without_installed_plan():
    chaos.uninstall()
    chaos.fire("engine.transform", Z=np.zeros((2, 2)))  # must not raise
    assert chaos.installed() is None


def test_poison_fault_is_content_bound():
    plan = FaultPlan([Fault(site="engine.transform", action="poison")])
    clean = np.zeros((4, 3), np.float32)
    dirty = clean.copy()
    dirty[2, 1] = chaos.POISON_SENTINEL
    plan.fire("engine.transform", Z=clean)  # order does not matter
    plan.fire("engine.transform", Z=clean)
    with pytest.raises(PoisonRequestError):
        plan.fire("engine.transform", Z=dirty)
    plan.fire("engine.transform", Z=clean)  # still clean after the hit


def test_transient_and_hang_actions(tmp_path):
    plan = FaultPlan(
        [
            Fault(site="a", at=1, action="raise_transient"),
            Fault(site="b", at=1, action="hang", hang_ms=5.0),
        ]
    )
    with pytest.raises(TransientEngineError):
        plan.fire("a")
    import time

    t0 = time.perf_counter()
    plan.fire("b")
    assert time.perf_counter() - t0 >= 0.004
    assert [f["action"] for f in plan.fired] == ["raise_transient", "hang"]


# ---------------------------------------------------------------------------
# batcher: degrade-don't-die
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rmodel():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (600, 3)).astype(np.float32)
    X[:, 2] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 600), 0, 1)
    return api.fit(X, method="oavi:fast", psi=0.01, backend="local", cap_terms=64)


@pytest.fixture(scope="module")
def rengine(rmodel):
    from repro.serving import EngineConfig

    eng = TransformEngine([rmodel], config=EngineConfig(min_bucket=32, max_bucket=512))
    eng.warmup()
    return eng


def _q(rows, seed=1):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 1, (rows, 3)).astype(np.float32)


def test_batcher_happy_path_bit_identical_coalesced(rengine):
    reqs = [_q(4, 1), _q(9, 2), _q(2, 3)]
    expected = [np.asarray(rengine.transform(r)) for r in reqs]
    bat = MicroBatcher(rengine, config=BatcherConfig(max_delay_ms=50.0))
    futs = [bat.submit(r) for r in reqs]
    bat.run_once()
    for f, e in zip(futs, expected):
        assert np.array_equal(f.result(), e)
    assert bat.stats["batches"] == 1  # actually coalesced
    assert bat.stats["retries"] == bat.stats["bisections"] == 0


def test_batcher_transient_failure_retries_bit_identical(rengine):
    reqs = [_q(4, 4), _q(6, 5)]
    expected = [np.asarray(rengine.transform(r)) for r in reqs]
    chaos.install(
        FaultPlan([Fault(site="engine.transform", at=1, action="raise_transient")])
    )
    try:
        bat = MicroBatcher(
            rengine, config=BatcherConfig(max_delay_ms=50.0, backoff_ms=0.1)
        )
        futs = [bat.submit(r) for r in reqs]
        bat.run_once()
    finally:
        chaos.uninstall()
    for f, e in zip(futs, expected):
        assert np.array_equal(f.result(), e)
    assert bat.stats["retries"] == 1


def test_batcher_retry_exhaustion_fails_whole_batch(rengine):
    chaos.install(
        FaultPlan(
            [Fault(site="engine.transform", at=1, action="raise_transient", times=6)]
        )
    )
    try:
        bat = MicroBatcher(
            rengine,
            config=BatcherConfig(max_delay_ms=50.0, max_retries=2, backoff_ms=0.1),
        )
        fut = bat.submit(_q(4, 6))
        bat.run_once()
        with pytest.raises(TransientEngineError):
            fut.result()
        # a second batch burns the remaining faults (occurrences 4..6)...
        fut2 = bat.submit(_q(4, 7))
        bat.run_once()
        with pytest.raises(TransientEngineError):
            fut2.result()
        # ...then the engine heals and serving resumes
        fut3 = bat.submit(_q(4, 8))
        bat.run_once()
        assert fut3.result().shape[0] == 4
    finally:
        chaos.uninstall()


def test_batcher_poison_request_fails_alone(rengine):
    good = [_q(4, 9), _q(7, 10), _q(3, 11)]
    expected = [np.asarray(rengine.transform(g)) for g in good]
    poison = _q(5, 12)
    poison[0, 0] = chaos.POISON_SENTINEL
    chaos.install(FaultPlan([Fault(site="engine.transform", action="poison")]))
    try:
        bat = MicroBatcher(rengine, config=BatcherConfig(max_delay_ms=50.0))
        futs = [bat.submit(g) for g in good]
        bad = bat.submit(poison)
        bat.run_once()
    finally:
        chaos.uninstall()
    with pytest.raises(PoisonRequestError):
        bad.result()
    for f, e in zip(futs, expected):
        assert np.array_equal(f.result(), e)  # innocent riders: bit-identical
    assert bat.stats["bisections"] >= 1
    assert bat.stats["isolated_failures"] == 1


def test_batcher_poison_isolation_can_be_disabled(rengine):
    poison = _q(3, 13)
    poison[1, 1] = chaos.POISON_SENTINEL
    chaos.install(FaultPlan([Fault(site="engine.transform", action="poison")]))
    try:
        bat = MicroBatcher(
            rengine, config=BatcherConfig(max_delay_ms=50.0, isolate_failures=False)
        )
        good_fut = bat.submit(_q(4, 14))
        bad_fut = bat.submit(poison)
        bat.run_once()
    finally:
        chaos.uninstall()
    # without isolation the whole coalesced batch fails together
    with pytest.raises(PoisonRequestError):
        bad_fut.result()
    with pytest.raises(PoisonRequestError):
        good_fut.result()


def test_batcher_deadline_expires_queued_request(rengine):
    import time

    bat = MicroBatcher(rengine, config=BatcherConfig(max_delay_ms=0.0))
    fut = bat.submit(_q(4, 15), deadline_ms=1.0)
    time.sleep(0.01)
    bat.run_once()
    with pytest.raises(DeadlineExceeded):
        fut.result()
    assert bat.stats["deadline_expired"] == 1
    # an un-deadlined request right behind it is unaffected
    fut2 = bat.submit(_q(4, 16))
    bat.run_once()
    assert fut2.result().shape[0] == 4


def test_batcher_stop_fails_pending_with_shutdown_error(rengine):
    """Satellite: stop() must never strand a future — undrained requests
    fail with ShutdownError, and submit-after-stop refuses loudly."""
    bat = MicroBatcher(rengine, config=BatcherConfig(max_delay_ms=0.0))
    futs = [bat.submit(_q(2, seed=20 + i)) for i in range(3)]
    bat.stop(drain=False)
    for f in futs:
        with pytest.raises(ShutdownError):
            f.result(timeout=5)
    assert bat.stats["shutdown_failed"] == 3
    with pytest.raises(ShutdownError, match="stopped"):
        bat.submit(_q(2))
    assert isinstance(ShutdownError("x"), RuntimeError)  # legacy catch sites


def test_registry_activation_failure_keeps_serving_old_version(rmodel):
    reg = ModelRegistry(warmup=False)
    reg.register("m", rmodel, activate=True)
    staged = reg.register("m", rmodel, activate=False)
    chaos.install(FaultPlan([Fault(site="registry.activate", at=1, action="raise")]))
    try:
        with pytest.raises(InjectedFault):
            reg.activate("m", staged.version)
    finally:
        chaos.uninstall()
    assert reg.active_version("m") == 1  # pointer never moved
    reg.activate("m", staged.version)  # transient fault: retry succeeds
    assert reg.active_version("m") == staged.version


# ---------------------------------------------------------------------------
# crash recovery end to end (subprocess SIGKILL at a journaled phase)
# ---------------------------------------------------------------------------


def test_continuous_kill_resume_bit_identical(tmp_path):
    """SIGKILL the controller at a random journaled phase transition; the
    resumed run must produce a final model bit-identical to an uninterrupted
    run, serve with zero mismatches, and re-fold with zero warm recompiles."""
    from repro.launch import chaos_vi

    ref_dir = str(tmp_path / "reference")
    proc = chaos_vi._run_controller(ref_dir)
    assert proc.returncode == 0, proc.stderr[-2000:]
    reference = chaos_vi._final_leaves(ref_dir)

    rng = np.random.default_rng(int(os.environ.get("CHAOS_SEED", "0")))
    phases = ["update_start", "state_saved", "staged", "activated"]
    phase = phases[int(rng.integers(0, len(phases)))]
    out = chaos_vi.scenario_kill_resume(str(tmp_path), reference, [(phase, 1)])
    assert out["ok"] and out["kills"][0]["caught_up_rows"] == 4096
