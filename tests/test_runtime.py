"""Checkpoint store, fault-tolerant loop, optimizer, and data-pipeline tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data import lm as lm_data
from repro.optim import AdamW, warmup_cosine
from repro.runtime import StepFailure, TrainLoop, TrainLoopConfig


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5, jnp.int32)}}
    store.save(str(tmp_path), 3, tree, {"k": "v"})
    got, meta = store.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(got["a"], np.arange(12.0).reshape(3, 4))
    np.testing.assert_array_equal(got["b"]["c"], np.ones(5))
    assert meta == {"k": "v"}


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.zeros(3)}
    path = store.save(str(tmp_path), 1, tree)
    store.save(str(tmp_path), 2, tree)
    # corrupt step 2: remove the marker
    os.remove(str(tmp_path / "step_00000002" / "COMMITTED"))
    assert store.latest_step(str(tmp_path)) == 1


def test_cleanup_keeps_last(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(6):
        store.save(str(tmp_path), s, tree)
    store.cleanup(str(tmp_path), keep_last=2)
    assert store.latest_step(str(tmp_path)) == 5
    with pytest.raises(FileNotFoundError):
        store.restore(str(tmp_path), 0, tree)


def test_async_saver_overlaps(tmp_path):
    saver = store.AsyncSaver()
    tree = {"a": jnp.arange(100.0)}
    saver.save(str(tmp_path), 1, tree)
    saver.save(str(tmp_path), 2, tree)  # waits for the first
    saver.wait()
    assert store.latest_step(str(tmp_path)) == 2


def test_trainloop_failure_injection_and_resume(tmp_path):
    fails = {3: 1, 7: 5}  # step 7 exhausts retries -> restore path
    counts = {}

    def injector(step):
        if counts.get(step, 0) < fails.get(step, 0):
            counts[step] = counts.get(step, 0) + 1
            raise StepFailure(f"injected@{step}")

    def step_fn(state, batch):
        return {"w": state["w"] + batch}, {"w": float(state["w"])}

    loop = TrainLoop(
        TrainLoopConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_retries_per_step=2),
        step_fn, lambda s: jnp.float32(1.0), {"w": jnp.zeros(())}, injector,
    )
    out = loop.run(10)
    assert out["final_step"] == 10
    assert out["restarts"] >= 4
    assert float(loop.state["w"]) == 10.0  # semantics preserved across restart


def test_trainloop_straggler_detection(tmp_path):
    import time

    def step_fn(state, batch):
        if batch == 5:
            time.sleep(0.3)
        return state, {}

    loop = TrainLoop(
        TrainLoopConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                        straggler_factor=5.0),
        step_fn, lambda s: s, {"w": jnp.zeros(())},
    )
    loop.run(8)
    assert any(e["step"] == 5 for e in loop.straggler_events)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lrw = float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lre = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10, total_steps=100))
    assert lr0 == 0.0 and abs(lrw - 1.0) < 1e-6 and lre == pytest.approx(0.1, rel=1e-3)


def test_adamw_quantized_matches_fp32():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    X = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)

    def loss(p):
        return jnp.mean((X @ p["w"] - y) ** 2)

    traces = []
    for quant in [False, True]:
        opt = AdamW(peak_lr=1e-2, warmup_steps=1, total_steps=50,
                    clip_norm=1.0, quantize_states=quant)
        p, st = params, opt.init(params)
        ls = []
        for _ in range(20):
            l, g = jax.value_and_grad(loss)(p)
            p, st = opt.update(p, g, st)
            ls.append(float(l))
        traces.append(ls)
    assert traces[0][-1] < traces[0][0]
    # 8-bit states track fp32 within a few percent
    assert abs(traces[1][-1] - traces[0][-1]) < 0.1 * abs(traces[0][0])


def test_grad_clipping_bounds_update():
    opt = AdamW(peak_lr=1.0, warmup_steps=0, total_steps=10, clip_norm=1e-3,
                weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    st = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = opt.update(params, huge, st)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


def test_grad_compression_error_feedback():
    from repro.optim import compress_grads, decompress_grads, init_residuals
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64,)), jnp.float32)}
    res = init_residuals(g)
    qs, res = compress_grads(g, res)
    back = decompress_grads(qs, g)
    # block-int8 quantization error bounded by scale/2
    err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"]))
    assert err.max() <= np.abs(np.asarray(g["w"])).max() / 127 + 1e-6
    # residual holds exactly the quantization error (error feedback)
    np.testing.assert_allclose(np.asarray(res["w"]),
                               np.asarray(g["w"]) - np.asarray(back["w"]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_lm_pipeline_determinism_and_sharding():
    cfg = lm_data.PipelineConfig(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    a = lm_data.global_batch_at(cfg, 5)
    b = lm_data.global_batch_at(cfg, 5)
    np.testing.assert_array_equal(a, b)
    parts = np.concatenate([
        lm_data.host_batch_at(cfg, 5, 0, 2),
        lm_data.host_batch_at(cfg, 5, 2, 4),
        lm_data.host_batch_at(cfg, 5, 6, 2),
    ])
    np.testing.assert_array_equal(a, parts)
    assert not (a == lm_data.global_batch_at(cfg, 6)).all()
    assert a.min() >= 0 and a.max() < 512


def test_frame_embeddings_unit_rms():
    x = np.asarray(lm_data.frame_embeddings(64, 16, 2, seed=0))
    rms = np.sqrt((x * x).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=0.05)
