"""Serving subsystem tests: engine buckets/sharding, batcher, registry."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.serving import (
    BatcherConfig,
    EngineConfig,
    MicroBatcher,
    ModelRegistry,
    TransformEngine,
    UnsupportedModelError,
    load_servable,
)

CFG = EngineConfig(min_bucket=32, max_bucket=512)


@pytest.fixture(scope="module")
def planted():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (900, 4)).astype(np.float32)
    X[:, 3] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 900), 0, 1)
    return X


@pytest.fixture(scope="module")
def labels(planted):
    return (planted[:, 0] > 0.5).astype(int)


@pytest.fixture(scope="module")
def models(planted, labels):
    return [
        api.fit(planted[labels == c], method="oavi:fast", psi=0.005,
                backend="local", cap_terms=64)
        for c in np.unique(labels)
    ]


@pytest.fixture(scope="module")
def classifier(planted, labels):
    clf = VanishingIdealClassifier(
        PipelineConfig(method="oavi:fast", psi=0.005, oavi_kw={"cap_terms": 64})
    )
    return clf.fit(planted, labels)


def _queries(q, seed=1):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 1, (q, 4)).astype(np.float32)


# -- engine -------------------------------------------------------------------


def test_engine_bit_identical_to_direct_path(models):
    eng = TransformEngine(models, config=CFG)
    for q in (1, 3, 32, 33, 100, 512, 700):
        Z = _queries(q, seed=q)
        direct = np.asarray(api.feature_transform(models, Z))
        served = eng.transform(Z)
        assert served.dtype == direct.dtype
        assert np.array_equal(served, direct), f"q={q} not bit-identical"


def test_engine_buckets_pow2_clamped(models):
    eng = TransformEngine(models, config=CFG)
    assert eng.buckets() == (32, 64, 128, 256, 512)
    assert eng.bucket_for(1) == 32
    assert eng.bucket_for(32) == 32
    assert eng.bucket_for(33) == 64
    assert eng.bucket_for(512) == 512
    assert eng.bucket_for(10_000) == 512  # clamped; larger requests chunk


def test_engine_ragged_sizes_one_compile_per_bucket(models):
    """Ragged request sizes across bucket boundaries pad correctly and
    trigger at most one compile per bucket."""
    eng = TransformEngine(models, config=CFG)
    sizes = [3, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129, 400]
    buckets_used = {eng.bucket_for(q) for q in sizes}
    for q in sizes:
        Z = _queries(q, seed=q)
        assert np.array_equal(
            eng.transform(Z), np.asarray(api.feature_transform(models, Z))
        )
    assert eng.stats["recompiles"] == len(buckets_used)
    # replaying the same ragged mix compiles nothing new
    before = eng.stats["recompiles"]
    for q in sizes:
        eng.transform(_queries(q, seed=q))
    assert eng.stats["recompiles"] == before
    assert eng.stats["padded_rows"] > 0


def test_engine_warmup_then_zero_recompiles(models):
    eng = TransformEngine(models, config=CFG)
    compiled = eng.warmup()
    assert compiled == len(eng.buckets())
    assert eng.warmup() == 0  # idempotent
    for q in (1, 17, 33, 129, 511, 2000):
        eng.transform(_queries(q, seed=q))
    assert eng.stats["recompiles"] == 0
    assert eng.stats["warmup_compiles"] == compiled


def test_engine_chunks_requests_beyond_max_bucket(models):
    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    Z = _queries(1100, seed=9)  # 512 + 512 + 76 -> 3 device calls
    out = eng.transform(Z)
    assert np.array_equal(out, np.asarray(api.feature_transform(models, Z)))
    assert eng.stats["device_calls"] == 3  # warmup tracked separately
    assert eng.stats["recompiles"] == 0


def test_engine_empty_request(models):
    eng = TransformEngine(models, config=CFG)
    out = eng.transform(np.zeros((0, 4), np.float32))
    assert out.shape == (0, eng.consts.num_features)


def test_engine_rejects_vca(planted):
    vca = api.fit(planted, method="vca", psi=0.005)
    with pytest.raises(UnsupportedModelError, match="term-book"):
        TransformEngine([vca], config=CFG)


def test_engine_rejects_wrong_width(models):
    eng = TransformEngine(models, config=CFG)
    with pytest.raises(ValueError, match="expected"):
        eng.transform(np.zeros((5, 7), np.float32))


def test_engine_config_validation():
    with pytest.raises(ValueError, match="min_bucket"):
        EngineConfig(min_bucket=64, max_bucket=32)


def test_feature_transform_engine_kwarg(models):
    eng = TransformEngine(models, config=CFG)
    Z = _queries(77)
    assert np.array_equal(
        np.asarray(api.feature_transform(models, Z, engine=eng)),
        np.asarray(api.feature_transform(models, Z)),
    )
    with pytest.raises(ValueError, match="different model set"):
        api.feature_transform(models[:1], Z, engine=eng)


def test_sharded_engine_matches_local_on_1device_mesh(models):
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    local = TransformEngine(models, config=CFG)
    sharded = TransformEngine(models, mesh=mesh, config=CFG)
    assert sharded.shards == 1
    for q in (3, 64, 100, 700):
        Z = _queries(q, seed=q)
        assert np.array_equal(sharded.transform(Z), local.transform(Z))


def test_sharded_engine_multi_device_subprocess():
    """Sharded == local on a real multi-shard mesh (fake CPU devices)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.abspath("src")
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro import api
        from repro.serving import EngineConfig, TransformEngine
        rng = np.random.default_rng(0)
        X = rng.uniform(0, 1, (600, 4)).astype(np.float32)
        X[:, 3] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 600), 0, 1)
        models = [api.fit(X, method="oavi:fast", psi=0.005, backend="local",
                          cap_terms=64)]
        cfg = EngineConfig(min_bucket=32, max_bucket=256)
        mesh = jax.make_mesh((4,), ("data",))
        local = TransformEngine(models, config=cfg)
        sharded = TransformEngine(models, mesh=mesh, config=cfg)
        assert sharded.shards == 4
        sharded.warmup()
        for q in (3, 30, 100, 300):
            Z = rng.uniform(0, 1, (q, 4)).astype(np.float32)
            a, b = local.transform(Z), sharded.transform(Z)
            assert a.shape == b.shape
            assert np.array_equal(a, b), q
        assert sharded.stats["recompiles"] == 0
        # a bucket must never divide to < 2 rows per shard (single-row
        # local matmuls lower as gemv and break bit-identity)
        tiny = TransformEngine(models, mesh=mesh,
                               config=EngineConfig(min_bucket=1, max_bucket=256))
        assert tiny.min_bucket >= 2 * tiny.shards, tiny.min_bucket
        for q in (1, 2, 5):
            Z = rng.uniform(0, 1, (q, 4)).astype(np.float32)
            assert np.array_equal(tiny.transform(Z), local.transform(Z)), q
        print("SHARDED-OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-OK" in out.stdout


# -- batcher ------------------------------------------------------------------


def test_batcher_run_once_coalesces_bit_exact(models):
    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    bat = MicroBatcher(eng, config=BatcherConfig(max_batch_rows=256))
    Zs = [_queries(q, seed=q) for q in (5, 17, 64, 9, 33)]
    futs = [bat.submit(Z) for Z in Zs]
    assert bat.run_once() == len(Zs)
    assert bat.stats["batches"] == 1  # 128 rows coalesce into one call
    for Z, f in zip(Zs, futs):
        assert np.array_equal(
            f.result(timeout=0), np.asarray(api.feature_transform(models, Z))
        )


def test_batcher_respects_max_batch_rows(models):
    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    bat = MicroBatcher(eng, config=BatcherConfig(max_batch_rows=64))
    futs = [bat.submit(_queries(40, seed=i)) for i in range(4)]
    bat.run_once()
    assert bat.stats["batches"] == 4  # 40+40 > 64: no pair fits one batch
    for f in futs:
        assert f.done()


def test_batcher_threaded_predict_and_transform(models, classifier):
    eng = TransformEngine(classifier.models, config=CFG)
    eng.warmup()
    Z = _queries(150, seed=2)
    Zs = classifier.scaler.transform(Z)
    with MicroBatcher(eng, head=classifier.head) as bat:
        f_t = bat.submit(Zs, "transform")
        f_p = bat.submit(Zs, "predict")
        feats = f_t.result(timeout=30)
        preds = f_p.result(timeout=30)
    assert np.array_equal(preds, classifier.predict(Z))
    assert np.array_equal(feats, classifier.transform(Z).astype(feats.dtype))


def test_batcher_config_validation():
    with pytest.raises(ValueError, match="max_queue"):
        BatcherConfig(max_queue=0)
    with pytest.raises(ValueError, match="max_batch_rows"):
        BatcherConfig(max_batch_rows=0)
    with pytest.raises(ValueError, match="max_delay_ms"):
        BatcherConfig(max_delay_ms=-1.0)


def test_batcher_unstarted_prequeue_beyond_max_queue_never_blocks(models):
    """run_once mode: backpressure only applies while a worker is running,
    so pre-queueing an open-loop trace can exceed max_queue freely."""
    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    bat = MicroBatcher(eng, config=BatcherConfig(max_queue=2))
    futs = [bat.submit(_queries(4, seed=i)) for i in range(6)]
    out = bat.transform(_queries(4))  # sync convenience drains everything
    assert out.shape[0] == 4 and all(f.done() for f in futs)


def test_batcher_predict_requires_head(models):
    bat = MicroBatcher(TransformEngine(models, config=CFG))
    with pytest.raises(ValueError, match="head"):
        bat.submit(_queries(4), "predict")
    with pytest.raises(ValueError, match="unknown request kind"):
        bat.submit(_queries(4), "decode")


def test_batcher_submit_after_stop_raises_then_restartable(models):
    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    bat = MicroBatcher(eng)
    bat.start()
    bat.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        bat.submit(_queries(4))
    bat.start()  # a stopped batcher can come back up
    try:
        assert bat.submit(_queries(4)).result(timeout=30).shape[0] == 4
    finally:
        bat.stop()


def test_batcher_rejects_malformed_requests_at_submit(models):
    """Shape errors surface at submit, never poisoning a coalesced batch."""
    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    bat = MicroBatcher(eng)
    with pytest.raises(ValueError, match="expected"):
        bat.submit(np.zeros((5, 9), np.float32))  # wrong width
    with pytest.raises(ValueError, match="expected"):
        bat.submit(np.zeros((4,), np.float32))  # wrong rank
    good = bat.submit(_queries(5))
    bat.run_once()
    assert good.result(timeout=0).shape == (5, eng.consts.num_features)


def test_batcher_propagates_processing_errors(models):
    def bad_head(feats):
        raise RuntimeError("head exploded")

    eng = TransformEngine(models, config=CFG)
    eng.warmup()
    bat = MicroBatcher(eng, head=bad_head)
    fut = bat.submit(_queries(5), "predict")
    ok = bat.submit(_queries(3))  # same batch, must still succeed
    bat.run_once()
    with pytest.raises(RuntimeError, match="head exploded"):
        fut.result(timeout=0)
    assert ok.result(timeout=0).shape[0] == 3


# -- classifier serialization + engine routing --------------------------------


def test_classifier_save_load_predict_bit_identical(classifier, planted, tmp_path):
    path = str(tmp_path / "clf")
    committed = classifier.save(path)
    assert os.path.exists(os.path.join(committed, "COMMITTED"))
    restored = VanishingIdealClassifier.load(path)
    Z = _queries(333, seed=11)
    assert np.array_equal(restored.predict(Z), classifier.predict(Z))
    assert np.array_equal(restored.transform(Z), classifier.transform(Z))
    assert restored.config.method == classifier.config.method
    assert np.array_equal(restored.classes_, classifier.classes_)


def test_classifier_load_rejects_model_checkpoint(models, tmp_path):
    api.save(models[0], str(tmp_path / "m"))
    with pytest.raises(ValueError, match="not a repro.vanishing_ideal_classifier"):
        VanishingIdealClassifier.load(str(tmp_path / "m"))


def test_classifier_save_unfitted_errors():
    clf = VanishingIdealClassifier()
    with pytest.raises(ValueError, match="unfitted"):
        clf.save("/tmp/nope")


def test_classifier_attach_engine_predict_identical(classifier, planted):
    Z = _queries(257, seed=12)
    base_pred = classifier.predict(Z)
    base_feat = classifier.transform(Z)
    eng = classifier.attach_engine(engine_config=CFG)
    try:
        assert eng is classifier.engine and eng.matches(classifier.models)
        assert np.array_equal(classifier.predict(Z), base_pred)
        assert np.array_equal(classifier.transform(Z), base_feat)
        assert eng.stats["requests"] >= 2
    finally:
        classifier.engine = None  # module-scoped fixture: leave it clean


def test_classifier_refit_drops_stale_engine(planted, labels):
    clf = VanishingIdealClassifier(
        PipelineConfig(method="oavi:fast", psi=0.005, oavi_kw={"cap_terms": 64})
    )
    clf.fit(planted, labels)
    clf.attach_engine(engine_config=CFG)
    assert clf.engine is not None
    clf.fit(planted, labels)  # refit: old engine no longer matches
    assert clf.engine is None


def test_classifier_attach_engine_vca_falls_back(planted, labels):
    clf = VanishingIdealClassifier(PipelineConfig(method="vca", psi=0.005))
    clf.fit(planted, labels)
    assert clf.attach_engine() is None and clf.engine is None
    assert clf.predict(planted[:32]).shape == (32,)  # per-model fallback


# -- registry -----------------------------------------------------------------


def test_registry_save_load_serve_bit_matches_direct(classifier, tmp_path):
    """The acceptance path: save -> registry.load -> serve bit-matches the
    direct feature_transform."""
    path = str(tmp_path / "clf")
    classifier.save(path)
    reg = ModelRegistry(engine_config=CFG)
    entry = reg.load("default", path)
    assert entry.engine is not None and entry.engine.stats["warmup_compiles"] > 0
    Z = _queries(181, seed=13)
    direct = np.asarray(
        api.feature_transform(list(entry.models), entry.scale(Z))
    )
    assert np.array_equal(entry.transform(Z), direct)
    assert np.array_equal(entry.predict(Z), classifier.predict(Z))
    assert entry.engine.stats["recompiles"] == 0
    assert entry.num_features == direct.shape[1]


def test_registry_load_single_model(models, tmp_path):
    api.save(models[0], str(tmp_path / "m"))
    reg = ModelRegistry(engine_config=CFG)
    entry = reg.load("gen", str(tmp_path / "m"))
    Z = _queries(64, seed=14)
    assert np.array_equal(
        entry.transform(Z), np.asarray(api.feature_transform(list(entry.models), Z))
    )
    with pytest.raises(ValueError, match="bare model"):
        entry.predict(Z)


def test_load_servable_dispatch(classifier, models, tmp_path):
    classifier.save(str(tmp_path / "c"))
    api.save(models[0], str(tmp_path / "m"))
    assert isinstance(load_servable(str(tmp_path / "c")), VanishingIdealClassifier)
    assert type(load_servable(str(tmp_path / "m"))) is type(models[0])
    with pytest.raises(FileNotFoundError):
        load_servable(str(tmp_path / "missing"))


def test_registry_hot_swap_versions(classifier):
    reg = ModelRegistry(engine_config=CFG, warmup=False)
    e1 = reg.register("default", classifier)
    e2 = reg.register("default", classifier)
    assert (e1.version, e2.version) == (1, 2)
    assert reg.active_version("default") == 2  # newest activates by default
    reg.activate("default", 1)
    assert reg.get("default").version == 1
    assert reg.get("default", version=2) is e2
    assert reg.versions("default") == (1, 2)
    staged = reg.register("default", classifier, activate=False)
    assert reg.active_version("default") == 1  # staging doesn't flip traffic
    reg.activate("default", staged.version)
    assert reg.get("default") is staged
    # a brand-new name registered staged has NO active version until
    # activate() — traffic must never resolve to an unvalidated model
    fresh = reg.register("fresh", classifier, activate=False)
    assert reg.active_version("fresh") is None
    with pytest.raises(KeyError, match="staged"):
        reg.get("fresh")
    reg.activate("fresh", fresh.version)
    assert reg.get("fresh") is fresh
    with pytest.raises(KeyError, match="no version"):
        reg.get("default", version=99)
    with pytest.raises(KeyError):
        reg.activate("default", 99)
    with pytest.raises(ValueError, match="already registered"):
        reg.register("default", classifier, version=1)


def test_registry_remove_repoints_active(classifier):
    reg = ModelRegistry(engine_config=CFG, warmup=False)
    reg.register("m", classifier)
    reg.register("m", classifier)
    reg.remove("m", 2)
    assert reg.active_version("m") == 1
    reg.remove("m")
    with pytest.raises(KeyError):
        reg.get("m")
    assert reg.names() == ()
    # removing the active version never flips traffic onto a staged one
    reg.register("s", classifier)  # v1, active
    reg.register("s", classifier, activate=False)  # v2, staged
    reg.remove("s", 1)
    assert reg.active_version("s") is None
    with pytest.raises(KeyError, match="staged"):
        reg.get("s")


# -- CLI driver ---------------------------------------------------------------


def test_serve_vi_cli_in_process(tmp_path):
    from repro.launch import serve_vi

    report = serve_vi.main([
        "--fit-m", "600", "--requests", "24", "--mean-rows", "32",
        "--concurrency", "4", "--min-bucket", "32", "--max-bucket", "512",
        "--model-dir", str(tmp_path / "ckpt"),
    ])
    assert report["requests"] == 24
    assert report["recompiles"] == 0
    assert report["rows_per_s"] > 0
    # second run exercises the checkpoint-load path
    report2 = serve_vi.main([
        "--requests", "8", "--mean-rows", "32", "--concurrency", "2",
        "--min-bucket", "32", "--max-bucket", "512", "--kind", "transform",
        "--model-dir", str(tmp_path / "ckpt"),
    ])
    assert report2["recompiles"] == 0
