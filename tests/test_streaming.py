"""Out-of-core OAVI (repro.streaming): sources, streaming scaler, and the
chunked Gram-statistics fit.

The load-bearing properties:

* the streamed fit is *bit-exact* against the in-memory fit at matched
  capacity, for every chunk size that is a multiple of the canonical Gram
  block, for both the closed-form ``fast`` engine and the convex-oracle
  configs — and through the 4-device sharded path against the in-memory
  sharded fit (subprocess, like test_distributed);
* results are chunk-size invariant (identical bits across {256, 1024, 4096});
* the streaming scaler matches the in-memory scaler bit for bit on every
  dtype the transform threads;
* a warm streaming refit compiles nothing.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api, streaming
from repro.core import oavi
from repro.core.oavi import OAVIConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import planted_source, planted_stream_tile, write_shards
from repro.kernels import ops as kernel_ops
from repro.streaming import (
    ArraySource,
    ScaledSource,
    ShardDirSource,
    StreamingMinMaxScaler,
    SyntheticSource,
    iter_chunks,
)

M = 3000


@pytest.fixture(scope="module")
def planted():
    """Raw planted-polynomial stream + its materialization + fitted scalers."""
    source = planted_source(M, n=3, seed=0)
    X_raw = np.asarray(source.read(0, M))
    scaler = StreamingMinMaxScaler(dtype="float32").fit_source(source, 1024)
    X = scaler.transform(X_raw)
    return source, X_raw, scaler, X


def _assert_models_bit_equal(a, b):
    assert a.book.terms == b.book.terms
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs), ga.term
        assert ga.mse == gb.mse


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------


def test_iter_chunks_pads_trailing_chunk():
    src = ArraySource(np.arange(10.0).reshape(5, 2))
    chunks = list(iter_chunks(src, 4))
    assert [c.shape for c, _ in chunks] == [(4, 2), (4, 2)]
    assert [v for _, v in chunks] == [4, 1]
    assert np.array_equal(chunks[1][0][1:], np.zeros((3, 2)))


def test_synthetic_source_chunking_invariant():
    """Reads are identical no matter how the row range is chunked — the
    property the planted tile generator is built for."""
    src = planted_source(10_000, n=3, seed=3)
    whole = src.read(0, 10_000)
    for rows in (256, 1024, 4096):
        got = np.concatenate(
            [c[:v] for c, v in iter_chunks(src, rows)], axis=0
        )
        assert np.array_equal(got, whole)
    # absolute-row determinism: a mid-stream read equals the slice
    assert np.array_equal(src.read(5000, 7000), whole[5000:7000])


def test_planted_tile_deterministic():
    a = planted_stream_tile(7, n=3, seed=11)
    b = planted_stream_tile(7, n=3, seed=11)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, planted_stream_tile(8, n=3, seed=11))


def test_shard_dir_source_round_trip(tmp_path, planted):
    source, X_raw, _, _ = planted
    path = str(tmp_path / "shards")
    meta = write_shards(path, source, shard_rows=1024)
    assert meta["num_shards"] == (M + 1023) // 1024
    sd = ShardDirSource(path)
    assert (sd.num_rows, sd.num_features) == (M, 3)
    assert np.array_equal(sd.read(0, M), X_raw.astype(np.float32))
    # cross-shard read
    assert np.array_equal(sd.read(1000, 2100), X_raw[1000:2100].astype(np.float32))


def test_shard_dir_rejects_wrong_format(tmp_path):
    import json

    (tmp_path / "meta.json").write_text(json.dumps({"format": "other"}))
    with pytest.raises(ValueError, match="repro.shards.v1"):
        ShardDirSource(str(tmp_path))


# ---------------------------------------------------------------------------
# streaming scaler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
def test_streaming_scaler_bit_exact_every_dtype(planted, dtype):
    """lo/scale statistics AND transformed outputs match the in-memory
    MinMaxScaler bit for bit on every dtype the transform threads."""
    source, X_raw, _, _ = planted
    ref = MinMaxScaler(dtype=dtype).fit(X_raw)
    for rows in (256, 1024, 4096):
        sc = StreamingMinMaxScaler(dtype=dtype).fit_source(source, rows)
        assert np.array_equal(sc.lo, ref.lo)
        assert np.array_equal(sc.scale, ref.scale)
        out_s = sc.transform(X_raw[:500])
        out_r = ref.transform(X_raw[:500])
        assert out_s.dtype == out_r.dtype
        assert np.array_equal(out_s, out_r)


def test_streaming_scaler_partial_fit_prefix_usable():
    rng = np.random.default_rng(0)
    X = rng.uniform(-3, 5, (100, 4))
    sc = StreamingMinMaxScaler()
    sc.partial_fit(X[:40])
    assert sc.scale is not None  # usable mid-stream
    sc.partial_fit(X[40:])
    ref = MinMaxScaler().fit(X)
    assert np.array_equal(sc.lo, ref.lo)
    assert np.array_equal(sc.scale, ref.scale)


def test_scaled_source_requires_fitted_scaler(planted):
    source = planted[0]
    with pytest.raises(ValueError, match="fitted"):
        ScaledSource(source, StreamingMinMaxScaler())


# ---------------------------------------------------------------------------
# streaming fit: bit-exactness and chunk-size invariance
# ---------------------------------------------------------------------------


def test_streaming_fit_bit_exact_fast_engine_all_chunk_sizes(planted):
    source, _, scaler, X = planted
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    ref = oavi.fit(X, cfg)
    scaled = ScaledSource(source, scaler)
    for rows in (256, 1024, 4096):
        _assert_models_bit_equal(streaming.fit(scaled, cfg, chunk_rows=rows), ref)


def test_streaming_fit_bit_exact_oracle_engine(planted):
    source, _, scaler, X = planted
    cfg = OAVIConfig(psi=0.005, engine="oracle", ihb=True, ordering="none",
                     cap_terms=64)
    ref = oavi.fit(X, cfg)
    scaled = ScaledSource(source, scaler)
    for rows in (512, 2048):
        _assert_models_bit_equal(streaming.fit(scaled, cfg, chunk_rows=rows), ref)


def test_streaming_fit_pearson_ordering_matches(planted):
    """The one-pass moment-based Pearson order reproduces the in-memory
    order on this data, and the resulting fit is bit-exact."""
    source, _, scaler, X = planted
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="pearson", cap_terms=64)
    ref = oavi.fit(X, cfg)
    mdl = streaming.fit(ScaledSource(source, scaler), cfg, chunk_rows=1024)
    assert np.array_equal(mdl.feature_perm, ref.feature_perm)
    _assert_models_bit_equal(mdl, ref)


def test_streaming_fit_warm_refit_zero_recompiles(planted):
    source, _, scaler, _ = planted
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    scaled = ScaledSource(source, scaler)
    first = streaming.fit(scaled, cfg, chunk_rows=1024)
    assert first.stats["recompiles"] >= 0  # cold count depends on cache state
    warm = streaming.fit(scaled, cfg, chunk_rows=1024)
    assert warm.stats["recompiles"] == 0
    assert warm.stats["streaming"]["chunk_rows"] == 1024
    assert warm.stats["streaming"]["num_chunks"] > 0


def test_streaming_fit_regrowth_matches_in_memory(planted):
    """Tiny initial capacity forces device-side regrowth in both paths."""
    source, _, scaler, X = planted
    cfg = OAVIConfig(psi=0.0005, engine="fast", ordering="none", cap_terms=8,
                     max_degree=3)
    ref = oavi.fit(X, cfg)
    mdl = streaming.fit(ScaledSource(source, scaler), cfg, chunk_rows=512)
    assert mdl.stats["regrowths"] == ref.stats["regrowths"] > 0
    _assert_models_bit_equal(mdl, ref)


def test_streaming_fit_rejects_bad_chunk_rows(planted):
    source, _, scaler, _ = planted
    scaled = ScaledSource(source, scaler)
    for bad in (100, 128, 384):
        with pytest.raises(ValueError, match="chunk_rows"):
            streaming.fit(scaled, OAVIConfig(), chunk_rows=bad)


@settings(max_examples=5, deadline=None)
@given(st.sampled_from([256, 512, 1024, 2048, 4096]))
def test_streaming_fit_chunk_size_invariance_property(chunk_rows):
    """Hypothesis sweep: every legal chunk size produces identical bits."""
    source = planted_source(1500, n=3, seed=5)
    scaler = StreamingMinMaxScaler(dtype="float32").fit_source(source, 512)
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    ref = oavi.fit(scaler.transform(source.read(0, 1500)), cfg)
    mdl = streaming.fit(ScaledSource(source, scaler), cfg, chunk_rows=chunk_rows)
    _assert_models_bit_equal(mdl, ref)


def test_streaming_fit_classes_bit_exact_multi_engine():
    """Streaming multi-class fit (one vmapped stats step per degree, no row
    padding) is bit-exact against per-class in-memory fits — for the fast
    engine AND the oracle engines through the fixed-schedule solvers."""
    from repro.core.oracles import OracleConfig

    sizes = [1500, 900, 1200]
    sources = [planted_source(m, n=3, seed=40 + i) for i, m in enumerate(sizes)]
    scalers = [
        StreamingMinMaxScaler(dtype="float32").fit_source(s, 512) for s in sources
    ]
    scaled = [ScaledSource(s, sc) for s, sc in zip(sources, scalers)]
    Xs = [sc.transform(np.asarray(s.read(0, m)))
          for s, sc, m in zip(sources, scalers, sizes)]

    configs = [
        OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64),
        OAVIConfig(psi=0.005, engine="oracle", solver=OracleConfig(name="bpcg"),
                   ihb=True, ordering="none", cap_terms=64),
    ]
    for cfg in configs:
        models = streaming.fit_classes(scaled, cfg, chunk_rows=512)
        for X, mdl in zip(Xs, models):
            _assert_models_bit_equal(mdl, oavi.fit(X, cfg))
        assert all(m.stats["class_batch"]["streaming"] for m in models)
        assert all(m.stats["class_batch"]["m_cap"] is None for m in models)
        warm = streaming.fit_classes(scaled, cfg, chunk_rows=512)
        assert warm[0].stats["recompiles"] == 0


def test_api_fit_classes_streaming_route():
    """api.fit_classes with chunk_rows routes through the streaming class
    batch and tags stats accordingly."""
    rng = np.random.default_rng(3)
    Xs = [rng.uniform(0, 1, (m, 3)).astype(np.float32) for m in (700, 500)]
    models = api.fit_classes(Xs, "oavi:fast", psi=0.005, cap_terms=64,
                             chunk_rows=256)
    assert all(m.stats["api"]["streaming"] for m in models)
    assert all(m.stats["api"]["class_batch"] for m in models)
    for X, mdl in zip(Xs, models):
        ref = api.fit(X, "oavi:fast", psi=0.005, cap_terms=64)
        assert mdl.book.terms == ref.book.terms
        assert [g.term for g in mdl.generators] == [g.term for g in ref.generators]


def test_gram_accumulate_chunked_equals_one_shot():
    """The kernel-level contract: carrying the accumulator across row chunks
    is bit-identical to one call over the concatenated rows."""
    rng = np.random.default_rng(0)
    m, L, n, K = 2048, 16, 4, 8
    A = rng.uniform(0, 1, (m, L)).astype(np.float32)
    X = rng.uniform(0, 1, (m, n)).astype(np.float32)
    parents = rng.integers(0, L, K).astype(np.int32)
    vars_ = rng.integers(0, n, K).astype(np.int32)
    one_shot = kernel_ops.gram_accumulate(A, X, parents, vars_)
    for rows in (256, 512, 1024):
        acc = None
        for lo in range(0, m, rows):
            acc = kernel_ops.gram_accumulate(
                A[lo : lo + rows], X[lo : lo + rows], parents, vars_, acc=acc
            )
        for a, b in zip(acc, one_shot):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    # and the interpret-mode Pallas kernel implements the same reduction
    ql_i, c_i = kernel_ops.gram_accumulate(A, X, parents, vars_, interpret=True)
    assert np.array_equal(np.asarray(ql_i), np.asarray(one_shot[0]))
    assert np.array_equal(np.asarray(c_i), np.asarray(one_shot[1]))


# ---------------------------------------------------------------------------
# api / pipeline integration
# ---------------------------------------------------------------------------


def test_api_fit_source_dispatch(planted):
    source, _, scaler, X = planted
    cfg_kw = dict(psi=0.005, ordering="none", cap_terms=64)
    ref = api.fit(X, "oavi:fast", backend="local", **cfg_kw)
    mdl = api.fit(
        ScaledSource(source, scaler), "oavi:fast", backend="local",
        chunk_rows=1024, **cfg_kw
    )
    assert mdl.stats["api"]["streaming"] is True
    _assert_models_bit_equal(mdl, ref)
    # explicit source= kwarg is equivalent
    mdl2 = api.fit(
        None, "oavi:fast", backend="local",
        source=ScaledSource(source, scaler), chunk_rows=1024, **cfg_kw
    )
    _assert_models_bit_equal(mdl2, ref)


def test_api_fit_source_rejects_non_oavi(planted):
    source, _, scaler, _ = planted
    with pytest.raises(ValueError, match="OAVI only"):
        api.fit(ScaledSource(source, scaler), "vca")


def test_classifier_streaming_chunk_rows_bit_identical(appc_small):
    """PipelineConfig(chunk_rows=...) routes per-class fits out-of-core and
    reproduces the in-memory classifier exactly (class_batch is bypassed, so
    compare against class_batch='off')."""
    from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier

    Xtr, ytr, Xte, yte = appc_small
    kw = dict(method="fast", psi=0.01, oavi_kw={"cap_terms": 64, "ordering": "none"})
    ref = VanishingIdealClassifier(PipelineConfig(class_batch="off", **kw))
    ref.fit(Xtr, ytr)
    clf = VanishingIdealClassifier(PipelineConfig(chunk_rows=512, **kw))
    clf.fit(Xtr, ytr)
    for a, b in zip(clf.models, ref.models):
        _assert_models_bit_equal(a, b)
    assert np.array_equal(clf.predict(Xte), ref.predict(Xte))


def test_classifier_streaming_save_load_round_trip(appc_small, tmp_path):
    from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier

    Xtr, ytr, Xte, _ = appc_small
    clf = VanishingIdealClassifier(
        PipelineConfig(method="fast", psi=0.01, chunk_rows=512,
                       oavi_kw={"cap_terms": 64})
    )
    clf.fit(Xtr, ytr)
    path = str(tmp_path / "clf")
    clf.save(path)
    loaded = VanishingIdealClassifier.load(path)
    assert loaded.config.chunk_rows == 512
    assert np.array_equal(loaded.predict(Xte), clf.predict(Xte))


# ---------------------------------------------------------------------------
# memory accounting (satellite)
# ---------------------------------------------------------------------------


def test_fit_stats_record_memory(planted):
    """peak_bytes only where the device allocator reports it (gracefully
    absent on CPU); live-array accounting always measured."""
    source, _, scaler, X = planted
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    mem = oavi.device_memory_stats()
    for stats in (oavi.fit(X, cfg).stats,
                  streaming.fit(ScaledSource(source, scaler), cfg).stats):
        if "peak_bytes_in_use" in mem:
            assert stats["peak_bytes"] > 0
        else:
            assert "peak_bytes" not in stats
        assert stats["live_bytes_peak"] > 0


def test_streaming_memory_stays_chunk_bounded(planted):
    """The streamed fit's live device footprint must not scale with m: at
    4x the rows it stays within 1.5x (the in-memory fit's A alone grows 4x)."""
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    peaks = []
    for m in (4096, 16384):
        src = planted_source(m, n=3, seed=2)
        sc = StreamingMinMaxScaler(dtype="float32").fit_source(src, 1024)
        mdl = streaming.fit(ScaledSource(src, sc), cfg, chunk_rows=1024)
        peaks.append(mdl.stats["live_bytes_peak"])
    assert peaks[1] <= 1.5 * peaks[0], peaks


# ---------------------------------------------------------------------------
# sharded streaming (subprocess: fake devices must not leak into the session)
# ---------------------------------------------------------------------------


def _run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_streaming_sharded_4_devices_bit_exact_subprocess():
    """Streaming over a 4-device mesh: each shard streams its local chunks,
    one psum per degree — bit-exact vs the in-memory sharded fit (same row
    partition, same blocked reduction, same collective)."""
    out = _run_sub("""
        import numpy as np, jax
        from repro.core import distributed
        from repro.core.oavi import OAVIConfig
        from repro import streaming
        from repro.streaming import ScaledSource, StreamingMinMaxScaler
        from repro.data.synthetic import planted_source

        m = 3001  # not divisible by 4 -> padded-span path
        src = planted_source(m, n=3, seed=0)
        sc = StreamingMinMaxScaler(dtype="float32").fit_source(src, 1024)
        X = sc.transform(src.read(0, m))
        mesh = jax.make_mesh((4,), ("data",))
        for cfg in (
            OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64),
            OAVIConfig(psi=0.005, engine="oracle", ihb=True, ordering="none",
                       cap_terms=64),
        ):
            ref = distributed.fit(X, cfg, mesh=mesh)
            for rows in (256, 1024):
                mdl = streaming.fit(ScaledSource(src, sc), cfg,
                                    chunk_rows=rows, mesh=mesh)
                assert mdl.book.terms == ref.book.terms
                for ga, gb in zip(mdl.generators, ref.generators):
                    assert np.array_equal(ga.coeffs, gb.coeffs), (cfg.engine, rows)
            warm = streaming.fit(ScaledSource(src, sc), cfg,
                                 chunk_rows=1024, mesh=mesh)
            assert warm.stats["recompiles"] == 0
        print("OK")
    """)
    assert "OK" in out
