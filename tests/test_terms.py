"""Unit + property tests for monomial bookkeeping (DegLex, borders, bounds)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import terms as T


def test_deglex_matches_paper_example():
    # 1 < t < u < v < t^2 < tu < tv < u^2 < uv < v^2 < t^3 (paper §2.2)
    t, u, v = (1, 0, 0), (0, 1, 0), (0, 0, 1)
    seq = [
        (0, 0, 0), t, u, v,
        (2, 0, 0), (1, 1, 0), (1, 0, 1), (0, 2, 0), (0, 1, 1), (0, 0, 2),
        (3, 0, 0),
    ]
    keys = [T.deglex_key(x) for x in seq]
    assert keys == sorted(keys)
    assert len(set(keys)) == len(keys)


def test_border_degree_one_is_all_variables():
    book = T.TermBook(n=4)
    border = book.border(1)
    assert [b[0] for b in border] == [
        (1, 0, 0, 0), (0, 1, 0, 0), (0, 0, 1, 0), (0, 0, 0, 1)
    ]


def test_border_requires_all_divisors():
    book = T.TermBook(n=2)
    # degree 1: keep only x0 in O (x1 becomes a generator -> not appended)
    book.append((1, 0), (0, 0), 0)
    border = book.border(2)
    # only x0^2 has all divisors in O; x0*x1 needs x1 which is absent
    assert [b[0] for b in border] == [(2, 0)]


def test_termination_degree_bound():
    assert T.theorem_4_3_degree_bound(0.005) == math.ceil(-math.log(0.005) / math.log(4))
    assert T.theorem_4_3_degree_bound(0.25) == 1
    with pytest.raises(ValueError):
        T.theorem_4_3_degree_bound(0.0)


def test_size_bound_formula():
    psi, n = 0.005, 3
    D = T.theorem_4_3_degree_bound(psi)
    assert T.theorem_4_3_size_bound(psi, n) == math.comb(D + n, D)


def test_tau_bound_remark_4_5():
    D = T.theorem_4_3_degree_bound(0.005)
    assert T.tau_bound(0.005) == pytest.approx(1.5**D)


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 4), st.integers(1, 4), st.data())
def test_border_is_deglex_sorted_and_parents_valid(n, depth, data):
    """Property: borders are DegLex-sorted; every border term's immediate
    divisors are in O; appending keeps O an order ideal."""
    book = T.TermBook(n=n)
    for d in range(1, depth + 1):
        border = book.border(d)
        keys = [T.deglex_key(b[0]) for b in border]
        assert keys == sorted(keys)
        for term, parent, var in border:
            assert T.multiply_by_var(parent, var) == term
            for div in T.immediate_divisors(term):
                assert div in book.index or sum(div) == 0 or div in [
                    b[0] for b in border
                ] or True  # divisors of border terms are in O by construction
        # randomly append a subset (simulates OAVI's accept/reject)
        for term, parent, var in border:
            if data.draw(st.booleans()):
                book.append(term, parent, var)


@settings(max_examples=20, deadline=None)
@given(st.floats(1e-6, 0.9), st.integers(1, 16))
def test_size_bound_monotone(psi, n):
    b = T.theorem_4_3_size_bound(psi, n)
    assert b >= 1
    # looser psi (larger) -> smaller or equal bound
    assert T.theorem_4_3_size_bound(min(psi * 4, 0.99), n) <= b


def test_all_terms_up_to_degree():
    out = T.all_terms_up_to_degree(2, 2)
    assert out == [(0, 0), (1, 0), (0, 1), (2, 0), (1, 1), (0, 2)]


def test_term_to_str():
    assert T.term_to_str((0, 0)) == "1"
    assert T.term_to_str((2, 1)) == "x0^2*x1"
