"""Baseline algorithms: ABM and VCA behaviour tests."""

import numpy as np
import pytest

from repro.core import abm, vca


def test_abm_generators_monic_and_vanishing(planted_cube):
    model = abm.fit(planted_cube, abm.ABMConfig(psi=0.005, cap_terms=64))
    assert model.num_G > 0
    # ABM acceptance is on the unit-norm polynomial (spurious-vanishing-prone
    # per the paper) — monic MSE may exceed psi but must stay small
    assert np.asarray(model.mse(planted_cube)).max() < 0.1


def test_abm_finds_planted_relation(planted_cube):
    model = abm.fit(planted_cube, abm.ABMConfig(psi=0.005, cap_terms=64))
    leads = {g.term for g in model.generators}
    # the relation x3 = x0*x1 should produce a degree-<=2 generator whose
    # leading term involves x3 or x0*x1
    assert any(t[3] > 0 or (t[0] and t[1]) for t in leads)


def test_vca_train_eval_consistency(planted_cube):
    model = vca.fit(planted_cube, vca.VCAConfig(psi=0.005))
    # replaying the construction tree on the training data reproduces
    # vanishing components
    assert model.num_G > 0
    assert model.mse(planted_cube).max() <= 0.005 * (1 + 1e-4)


def test_vca_eval_new_points(planted_cube):
    model = vca.fit(planted_cube, vca.VCAConfig(psi=0.005))
    rng = np.random.default_rng(1)
    Z = rng.uniform(0, 1, (200, 4))
    Z[:, 3] = np.clip(Z[:, 0] * Z[:, 1], 0, 1)
    G = model.evaluate_G(Z)
    assert G.shape == (200, model.num_G)
    assert np.isfinite(G).all()


def test_vca_is_permutation_invariant(planted_cube):
    """Monomial-agnostic methods are data-driven by construction (§1.2)."""
    perm = np.array([2, 0, 3, 1])
    a = vca.fit(planted_cube, vca.VCAConfig(psi=0.005))
    b = vca.fit(planted_cube[:, perm], vca.VCAConfig(psi=0.005))
    assert a.num_G == b.num_G
    np.testing.assert_allclose(
        np.sort(np.abs(a.evaluate_G(planted_cube)), axis=None),
        np.sort(np.abs(b.evaluate_G(planted_cube[:, perm])), axis=None),
        rtol=5e-2, atol=5e-3,
    )


def test_vca_spurious_vanishing_on_many_features():
    """The paper's §6.2: VCA constructs many more components on
    high-dimensional data (spam-like n) than monomial-aware methods."""
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (300, 12))
    v = vca.fit(X, vca.VCAConfig(psi=0.005, max_degree=3))
    a = abm.fit(X, abm.ABMConfig(psi=0.005, cap_terms=256, max_degree=3))
    assert v.num_G >= a.num_G
