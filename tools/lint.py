"""Dead-import linter for ``make lint``.

Prefers ``pyflakes`` when installed (``make dev-deps`` /
requirements-dev.txt); otherwise falls back to a built-in AST check for
unused imports, so the target works in the bare runtime container too.

    python tools/lint.py [paths...]     (default: src/repro benchmarks tools)

Exits non-zero when any unused import (pyflakes: any warning) is found.
"""

from __future__ import annotations

import ast
import pathlib
import subprocess
import sys

DEFAULT_PATHS = ["src/repro", "benchmarks", "tools"]


def _pyflakes(paths) -> int:
    proc = subprocess.run([sys.executable, "-m", "pyflakes", *paths])
    return proc.returncode


def _unused_imports(path: pathlib.Path):
    tree = ast.parse(path.read_text())
    imports = {}  # bound name -> (lineno, dotted origin)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = (a.asname or a.name).split(".")[0]
                imports[name] = (node.lineno, a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                imports[a.asname or a.name] = (
                    node.lineno,
                    f"{node.module}.{a.name}" if node.module else a.name,
                )
    used = set()

    class Visitor(ast.NodeVisitor):
        def visit_Name(self, node):
            used.add(node.id)

        def visit_Attribute(self, node):
            inner = node
            while isinstance(inner, ast.Attribute):
                inner = inner.value
            if isinstance(inner, ast.Name):
                used.add(inner.id)
            self.generic_visit(node)

        def visit_Constant(self, node):
            # count string constants as uses: __all__ entries and quoted
            # forward-reference annotations refer to names by string
            if isinstance(node.value, str):
                used.add(node.value)

    Visitor().visit(tree)
    return [
        (lineno, origin, name)
        for name, (lineno, origin) in sorted(imports.items(), key=lambda kv: kv[1])
        if name not in used
    ]


def _fallback(paths) -> int:
    failures = 0
    for root in paths:
        root = pathlib.Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            for lineno, origin, name in _unused_imports(f):
                print(f"{f}:{lineno}: '{origin}' imported but unused (as {name!r})")
                failures += 1
    if failures:
        print(f"\n{failures} unused import(s)", file=sys.stderr)
    return 1 if failures else 0


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or DEFAULT_PATHS
    try:
        import pyflakes  # noqa: F401

        return _pyflakes(paths)
    except ImportError:
        return _fallback(paths)


if __name__ == "__main__":
    raise SystemExit(main())
