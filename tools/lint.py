"""Linter for ``make lint``: unused imports, solver-loop and clock discipline.

Unused imports: prefers ``pyflakes`` when installed (``make dev-deps`` /
requirements-dev.txt); otherwise falls back to a built-in AST check, so the
target works in the bare runtime container too.

Clock discipline: ``time.time()`` is banned in ``src/repro`` — it is a
wall clock (NTP steps it backwards), so measuring durations with it yields
negative or torn intervals exactly when the machine is under stress.  Every
duration must come from ``time.perf_counter()`` (or ``time.monotonic``);
the few legitimate *timestamp* uses (e.g. a registry entry's ``loaded_at``)
are named in ``TIME_TIME_ALLOWLIST``.

Solver-loop discipline: the batched-solver modules must not grow new
data-dependent ``lax.while_loop``s — a while_loop under ``vmap`` runs every
lane to the max trip count with no escape for converged lanes, and its trip
count is invisible to the schedule-budget machinery.  Any new bounded loop
there must follow the shared-parts discipline of :mod:`repro.core.oracles`:
write ``cond``/``body``/``finish`` closures and run them through BOTH
``_run_while`` (the while_loop ref, for sequential fits and parity tests)
and ``_run_scheduled`` (the masked fixed-schedule twin the batched paths
use).  ``WHILE_LOOP_ALLOWLIST`` names the one wrapper that legitimately
calls ``while_loop``.

    python tools/lint.py [paths...]     (default: src/repro benchmarks tools)

Exits non-zero on any finding.
"""

from __future__ import annotations

import ast
import pathlib
import subprocess
import sys

DEFAULT_PATHS = ["src/repro", "benchmarks", "tools"]

# module (repo-relative) -> function names allowed to call lax.while_loop
WHILE_LOOP_ALLOWLIST = {
    "src/repro/core/oracles.py": {"_run_while"},
    "src/repro/core/oavi.py": set(),
}

# module (repo-relative) -> function names allowed to call time.time():
# genuine wall-clock *timestamps*, never duration measurement
TIME_TIME_ALLOWLIST = {
    "src/repro/serving/registry.py": {"register"},  # loaded_at timestamp
}

# only library code is clock-checked; benchmarks/tools may timestamp freely
TIME_TIME_ROOT = "src/repro"


def _enclosing_functions(tree: ast.AST):
    """Map every node to the name of its innermost enclosing function."""
    owner = {}

    def walk(node, fn_name):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn_name = node.name
        for child in ast.iter_child_nodes(node):
            owner[child] = fn_name
            walk(child, fn_name)

    walk(tree, None)
    return owner


def _while_loop_violations(path: pathlib.Path, repo_root: pathlib.Path):
    """Flag ``lax.while_loop`` calls outside the allowlisted wrappers.

    Matches any call whose callee is literally named ``while_loop`` — as an
    attribute (``jax.lax.while_loop``, ``lax.while_loop``) or a bare name
    (``from jax.lax import while_loop``) — in the modules named by
    ``WHILE_LOOP_ALLOWLIST``.  Other modules are not checked: the discipline
    is about the batched-solver core, not the whole tree.
    """
    try:
        rel = str(path.resolve().relative_to(repo_root))
    except ValueError:
        rel = str(path)
    allowed = WHILE_LOOP_ALLOWLIST.get(rel)
    if allowed is None:
        return []
    tree = ast.parse(path.read_text())
    owner = _enclosing_functions(tree)
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = node.func
        name = None
        if isinstance(callee, ast.Attribute):
            name = callee.attr
        elif isinstance(callee, ast.Name):
            name = callee.id
        if name != "while_loop":
            continue
        fn = owner.get(node)
        if fn not in allowed:
            where = f"in {fn}()" if fn else "at module level"
            findings.append(
                (
                    node.lineno,
                    f"data-dependent lax.while_loop {where} — batched solver "
                    f"modules must use the shared-parts discipline "
                    f"(cond/body/finish through _run_while AND _run_scheduled); "
                    f"allowlisted wrappers for this module: "
                    f"{sorted(allowed) or '(none)'}",
                )
            )
    return findings


def _check_while_loops(paths) -> int:
    repo_root = pathlib.Path(__file__).resolve().parent.parent
    failures = 0
    for root in paths:
        root = pathlib.Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            for lineno, msg in _while_loop_violations(f, repo_root):
                print(f"{f}:{lineno}: {msg}")
                failures += 1
    if failures:
        print(f"\n{failures} while_loop discipline violation(s)", file=sys.stderr)
    return 1 if failures else 0


def _time_time_violations(path: pathlib.Path, repo_root: pathlib.Path):
    """Flag ``time.time()`` calls in library code outside the allowlist.

    Matches ``time.time()`` attribute calls and bare ``time()`` calls bound
    by ``from time import time``.  Only files under ``TIME_TIME_ROOT`` are
    checked; allowlisted (module, function) pairs are wall-clock timestamps,
    not duration measurements.
    """
    try:
        rel = str(path.resolve().relative_to(repo_root))
    except ValueError:
        rel = str(path)
    if not rel.startswith(TIME_TIME_ROOT):
        return []
    tree = ast.parse(path.read_text())
    # does this module bind the bare name `time` to the function (not module)?
    bare_time = any(
        isinstance(node, ast.ImportFrom) and node.module == "time"
        and any(a.name == "time" and a.asname is None for a in node.names)
        for node in ast.walk(tree)
    )
    owner = _enclosing_functions(tree)
    allowed = TIME_TIME_ALLOWLIST.get(rel, set())
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = node.func
        hit = (
            isinstance(callee, ast.Attribute)
            and callee.attr == "time"
            and isinstance(callee.value, ast.Name)
            and callee.value.id == "time"
        ) or (bare_time and isinstance(callee, ast.Name) and callee.id == "time")
        if not hit:
            continue
        fn = owner.get(node)
        if fn in allowed:
            continue
        where = f"in {fn}()" if fn else "at module level"
        findings.append(
            (
                node.lineno,
                f"time.time() {where} — wall clocks step backwards; use "
                f"time.perf_counter() for durations (or add a genuine "
                f"timestamp use to TIME_TIME_ALLOWLIST)",
            )
        )
    return findings


def _check_time_time(paths) -> int:
    repo_root = pathlib.Path(__file__).resolve().parent.parent
    failures = 0
    for root in paths:
        root = pathlib.Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            for lineno, msg in _time_time_violations(f, repo_root):
                print(f"{f}:{lineno}: {msg}")
                failures += 1
    if failures:
        print(f"\n{failures} clock discipline violation(s)", file=sys.stderr)
    return 1 if failures else 0


def _pyflakes(paths) -> int:
    proc = subprocess.run([sys.executable, "-m", "pyflakes", *paths])
    return proc.returncode


def _unused_imports(path: pathlib.Path):
    tree = ast.parse(path.read_text())
    imports = {}  # bound name -> (lineno, dotted origin)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = (a.asname or a.name).split(".")[0]
                imports[name] = (node.lineno, a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                imports[a.asname or a.name] = (
                    node.lineno,
                    f"{node.module}.{a.name}" if node.module else a.name,
                )
    used = set()

    class Visitor(ast.NodeVisitor):
        def visit_Name(self, node):
            used.add(node.id)

        def visit_Attribute(self, node):
            inner = node
            while isinstance(inner, ast.Attribute):
                inner = inner.value
            if isinstance(inner, ast.Name):
                used.add(inner.id)
            self.generic_visit(node)

        def visit_Constant(self, node):
            # count string constants as uses: __all__ entries and quoted
            # forward-reference annotations refer to names by string
            if isinstance(node.value, str):
                used.add(node.value)

    Visitor().visit(tree)
    return [
        (lineno, origin, name)
        for name, (lineno, origin) in sorted(imports.items(), key=lambda kv: kv[1])
        if name not in used
    ]


def _fallback(paths) -> int:
    failures = 0
    for root in paths:
        root = pathlib.Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            for lineno, origin, name in _unused_imports(f):
                print(f"{f}:{lineno}: '{origin}' imported but unused (as {name!r})")
                failures += 1
    if failures:
        print(f"\n{failures} unused import(s)", file=sys.stderr)
    return 1 if failures else 0


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or DEFAULT_PATHS
    rc_loops = _check_while_loops(paths)
    rc_clock = _check_time_time(paths)
    try:
        import pyflakes  # noqa: F401

        rc_imports = _pyflakes(paths)
    except ImportError:
        rc_imports = _fallback(paths)
    return rc_loops or rc_clock or rc_imports


if __name__ == "__main__":
    raise SystemExit(main())
